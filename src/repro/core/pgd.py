"""Projected-gradient (Adam) reference solver for subproblem P4(P, X).

Cross-check for the paper-faithful KKT/SCA path in `p5.py` (DESIGN.md §8):
two independent solvers agreeing on toy instances is the validation story.

Parametrisation enforces the hard constraints *exactly* and without gradient
dead-zones:
  * per subcarrier k, (x_{1..N,k}, x_unassigned) = softmax over N+1 logits
    => constraint (13d)  sum_n x_{n,k} <= 1  holds by construction;
  * per device, a learnable power budget  B_n = Pmax_n * sigmoid(w_tot_n)
    and a per-subcarrier shape  P_raw = Pmax * x^q * sigmoid(w); the final
    P = P_raw * min(1, B_n / sum_k P_raw)  keeps (13a)+(13b) while the budget
    itself stays differentiable (a plain min(1, Pmax/sum) clamp has zero
    gradient to total power once it binds — that dead zone previously froze
    every solve at ~full power);
remaining soft constraints (rate floor r_n >= rmin_n) are squared hinges, and
a concave x(1-x) penalty (the paper's (32b)) pushes X to binary.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .system import device_rate
from .types import SystemParams

_EPS = 1e-12


class PGDConfig(NamedTuple):
    steps: int = 800
    lr: float = 0.08
    penalty_rate: float = 10.0
    penalty_binary: float = 0.3
    temp_end: float = 0.25  # final softmax temperature (anneals from 1.0)


def _adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mhat = m / (1 - b1**t)
    vhat = v / (1 - b2**t)
    return -lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def _logit(p):
    p = jnp.clip(p, 1e-5, 1.0 - 1e-5)
    return jnp.log(p) - jnp.log1p(-p)


def _budgeted_power(params: SystemParams, P_raw, w_tot):
    """P = P_raw * min(1, B_n / sum P_raw) with learnable budget B_n."""
    budget = params.p_max * jax.nn.sigmoid(w_tot)            # (N,)
    tot = jnp.maximum(jnp.sum(P_raw, -1), _EPS)
    return P_raw * jnp.minimum(1.0, budget / tot)[:, None]


def _decode(params: SystemParams, z, w, w_tot, temp):
    """(z logits (N+1,K), w (N,K), w_tot (N,)) -> feasible (P, X).

    Padded devices (dev_mask = 0, see `pad_params`) are excluded from the
    per-subcarrier softmax with a -1e9 logit — exp underflows to exactly 0,
    so the softmax over the remaining rows matches the exact-shape program —
    and padded subcarriers are zeroed so no power lands on them. All-ones
    masks reduce this to the unmasked decode bit-for-bit.
    """
    row_mask = jnp.concatenate(
        [params.dev_mask, jnp.ones((1,), params.dev_mask.dtype)]  # keep "unassigned"
    )
    z = jnp.where(row_mask[:, None] > 0.0, z, -1e9)
    x_full = jax.nn.softmax(z / temp, axis=0)        # (N+1, K)
    X = x_full[:-1] * params.sc_mask[None, :]        # drop the "unassigned" row
    q = float(params.q)
    P_raw = params.p_max[:, None] * (X**q) * jax.nn.sigmoid(w)
    return _budgeted_power(params, P_raw, w_tot), X


def solve_p4_pgd(
    params: SystemParams,
    kappa1,
    payload: jnp.ndarray,     # D_n + rho C_n  [bits]
    rmin: jnp.ndarray,        # (N,)
    P0: jnp.ndarray,
    X0: jnp.ndarray,
    cfg: PGDConfig = PGDConfig(),
):
    """Minimise kappa1 sum_n (sum_k p)(payload)/r_n  s.t. P1's comms constraints."""

    def loss(z, w, w_tot, temp):
        P, X = _decode(params, z, w, w_tot, temp)
        r = device_rate(params, P, X)
        frac = jnp.sum(P, -1) * payload / jnp.maximum(r, _EPS)
        hinge = jnp.square(jnp.maximum(rmin - r, 0.0) / jnp.maximum(rmin, 1.0))
        binary = jnp.sum(X * (1.0 - X))
        return (
            kappa1 * jnp.sum(frac)
            + cfg.penalty_rate * jnp.sum(hinge)
            + cfg.penalty_binary * binary
        )

    # warm start from (P0, X0)
    x_aug = jnp.concatenate(
        [jnp.clip(X0, 1e-3, 1.0), jnp.maximum(1.0 - jnp.sum(X0, 0, keepdims=True), 1e-3)], 0
    )
    z = jnp.log(x_aug)
    w = _logit(P0 / jnp.maximum(params.p_max[:, None] * jnp.clip(X0, 1e-3, 1.0) ** 2, _EPS))
    w_tot = _logit(jnp.sum(P0, -1) / params.p_max * 1.2)

    grad_fn = jax.grad(loss, argnums=(0, 1, 2))

    def step(state, i):
        z, w, w_tot, moms = state
        t = i + 1
        frac_done = i / max(cfg.steps - 1, 1)
        temp = 1.0 + (cfg.temp_end - 1.0) * frac_done
        gz, gw, gt = grad_fn(z, w, w_tot, temp)
        (mz, vz), (mw, vw), (mt, vt) = moms
        dz, mz, vz = _adam_update(gz, mz, vz, t, cfg.lr)
        dw, mw, vw = _adam_update(gw, mw, vw, t, cfg.lr)
        dt, mt, vt = _adam_update(gt, mt, vt, t, cfg.lr)
        return (z + dz, w + dw, w_tot + dt, ((mz, vz), (mw, vw), (mt, vt))), None

    zeros = lambda x: (jnp.zeros_like(x), jnp.zeros_like(x))
    state = (z, w, w_tot, (zeros(z), zeros(w), zeros(w_tot)))
    state, _ = jax.lax.scan(step, state, jnp.arange(cfg.steps, dtype=jnp.float32))
    P, X = _decode(params, state[0], state[1], state[2], cfg.temp_end)
    return P, X


def power_given_x(
    params: SystemParams,
    kappa1,
    payload: jnp.ndarray,
    rmin: jnp.ndarray,
    X: jnp.ndarray,           # binary (N, K)
    P0: jnp.ndarray | None = None,
    steps: int = 600,
    lr: float = 0.08,
    penalty_rate: float = 10.0,
):
    """Re-optimise powers after hardening X to binary (per-device separable)."""

    def decode(w, w_tot):
        P_raw = params.p_max[:, None] * X * jax.nn.sigmoid(w)
        return _budgeted_power(params, P_raw, w_tot)

    def loss(w, w_tot):
        P = decode(w, w_tot)
        r = device_rate(params, P, X)
        frac = jnp.sum(P, -1) * payload / jnp.maximum(r, _EPS)
        hinge = jnp.square(jnp.maximum(rmin - r, 0.0) / jnp.maximum(rmin, 1.0))
        return kappa1 * jnp.sum(frac) + penalty_rate * jnp.sum(hinge)

    if P0 is None:
        P0 = params.p_max[:, None] * X * 0.25
    w = _logit(P0 / jnp.maximum(params.p_max[:, None] * X, _EPS))
    w_tot = _logit(jnp.sum(P0, -1) / params.p_max * 1.2)
    grad_fn = jax.grad(loss, argnums=(0, 1))

    def step(state, i):
        w, w_tot, m, v, mt, vt = state
        g, gt = grad_fn(w, w_tot)
        dw, m, v = _adam_update(g, m, v, i + 1, lr)
        dt, mt, vt = _adam_update(gt, mt, vt, i + 1, lr)
        return (w + dw, w_tot + dt, m, v, mt, vt), None

    state = (w, w_tot, jnp.zeros_like(w), jnp.zeros_like(w),
             jnp.zeros_like(w_tot), jnp.zeros_like(w_tot))
    state, _ = jax.lax.scan(step, state, jnp.arange(steps, dtype=jnp.float32))
    return decode(state[0], state[1])
