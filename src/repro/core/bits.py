"""Upload-size accounting shared by the FL driver and the SemCom codec.

The allocator's D_n (bits a client uploads per round) must mean the same
thing wherever it is computed — `fl.federated` sizing the sparsified update
and `semcom.autoencoder` sizing the codec parameters used to diverge by
construction (two copies of the same expression). Both now delegate here.
"""
from __future__ import annotations

import jax


def tree_bits(tree, bits_per_param: int = 32) -> float:
    """Total size of a pytree's leaves in bits (float32 by default).

    This is the FL upload size D_n the allocator prices: every leaf entry
    costs ``bits_per_param`` bits on the uplink.
    """
    return float(
        sum(x.size for x in jax.tree_util.tree_leaves(tree)) * bits_per_param
    )
