"""Objective scoring routed through the batched `fedsem_objective` kernel.

`system.objective` scores ONE allocation for ONE scenario with plain jnp.
The hot paths score many at once — `solve`'s multi-start selection (G
candidate allocations per scenario, vmapped over B scenarios by
`solve_batch`), the serving layer's padded-bucket flushes (B scenarios, one
allocation each), the exhaustive grid sweep — and this module fuses those
evaluations into single calls of `repro.kernels.fedsem_objective.ops.
objective_grid_batch` (Pallas on TPU, the kernel's jnp oracle elsewhere;
``interpret=True`` runs the Pallas path on CPU for tests).

Equivalence guarantee: with ``check_feasible=False`` (the default here) the
kernel evaluates exactly eq. 13 — the same masked reductions as the
mask-aware `system.objective` — so scores agree with it to float32
round-off (a few ulps, from reduction/FMA ordering; asserted in
`tests/test_kernels.py`). Padded scenarios (`pad_params`) score identically
to their exact-shape twins: `dev_mask` excludes padded rows from the device
count, every energy/delay reduction, and the feasibility checks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .accuracy import AccuracyFn, default_accuracy
from .system import device_rate
from .types import Allocation, SystemParams, Weights


def candidate_objectives(
    params: SystemParams,
    weights: Weights,
    allocs: Allocation,
    accuracy: AccuracyFn | None = None,
    *,
    use_pallas: str | bool = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Score G candidate allocations for ONE scenario -> (G,) objectives.

    ``allocs`` is an `Allocation` whose leaves carry a leading candidate axis
    G (``f``: (G, N), ``P``/``X``: (G, N, K), ``rho``: (G,)). Rates are
    derived per candidate (eq. 2) and the eq. 13 scores are fused into one
    batched-kernel call with `system.objective` semantics (no feasibility
    masking). vmap-safe: under `solve_batch`'s vmap the per-scenario B=1
    Pallas call batches into an extra scenario grid dimension, so the whole
    multi-start selection of a batch is still one kernel launch.
    """
    from repro.kernels.fedsem_objective import ops

    acc = accuracy or default_accuracy()
    r = jax.vmap(lambda P, X: device_rate(params, P, X))(allocs.P, allocs.X)
    p_n = jnp.sum(allocs.P, axis=-1)                          # (G, N)
    rho = jnp.reshape(allocs.rho, (-1,))                      # (G,)
    obj = ops.objective_grid_batch(
        allocs.f[None], p_n[None], r[None], rho[None],
        params.c[None], params.d[None], params.D[None], params.C[None],
        params.t_sc_max[None], params.f_max[None],
        weights.kappa1, weights.kappa2, weights.kappa3,
        xi=float(params.xi), eta=float(params.eta),
        accuracy_ab=(acc.a, acc.b),
        dev_mask=params.dev_mask[None],
        check_feasible=False,
        use_pallas=use_pallas,
        interpret=interpret,
    )
    return obj[0]


def scenario_objective(
    params: SystemParams,
    weights: Weights,
    alloc: Allocation,
    accuracy: AccuracyFn | None = None,
    *,
    use_pallas: str | bool = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """`system.objective` via the kernel path: one scenario, one allocation."""
    one = jax.tree.map(lambda x: jnp.asarray(x)[None], alloc)
    return candidate_objectives(
        params, weights, one, accuracy,
        use_pallas=use_pallas, interpret=interpret,
    )[0]


def batch_objectives(
    params_batch: SystemParams,
    weights: Weights,
    allocs: Allocation,
    accuracy: AccuracyFn | None = None,
    *,
    weights_batched: bool = False,
    use_pallas: str | bool = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Score one allocation per scenario of a stacked batch -> (B,).

    ``params_batch`` is batch-stacked (`stack_params`, ``g``: (B, N, K)) and
    ``allocs`` carries matching leading-B leaves — e.g. the ``alloc`` of a
    `solve_batch` result, or a serving flush's padded bucket batch. This is
    the direct (un-vmapped) batched-kernel entry: the B scenarios land on the
    kernel's scenario grid axis with G = 1 candidate each. ``weights`` is
    broadcast unless ``weights_batched`` (leaves with a leading B axis).
    ``accuracy`` likewise takes either one scalar fit (broadcast) or a
    `stack_accuracy` batch with (B,) leaves — per-scenario accuracy
    coefficients are runtime kernel inputs exactly like per-scenario kappas,
    which is how the serving layer scores mixed-tenant flushes under each
    row's own A(rho) fit.
    """
    from repro.kernels.fedsem_objective import ops

    acc = accuracy or default_accuracy()
    r = jax.vmap(device_rate)(params_batch, allocs.P, allocs.X)   # (B, N)
    p_n = jnp.sum(allocs.P, axis=-1)                              # (B, N)
    kap = (weights.kappa1, weights.kappa2, weights.kappa3)
    if not weights_batched:
        b = p_n.shape[0]
        kap = tuple(jnp.broadcast_to(k, (b,)) for k in kap)
    obj = ops.objective_grid_batch(
        allocs.f[:, None, :], p_n[:, None, :], r[:, None, :],
        jnp.reshape(allocs.rho, (-1, 1)),
        params_batch.c, params_batch.d, params_batch.D, params_batch.C,
        params_batch.t_sc_max, params_batch.f_max,
        *kap,
        xi=float(params_batch.xi), eta=float(params_batch.eta),
        accuracy_ab=(acc.a, acc.b),
        dev_mask=params_batch.dev_mask,
        check_feasible=False,
        use_pallas=use_pallas,
        interpret=interpret,
    )
    return obj[:, 0]
