"""Scenario-parallel sharding for the batched allocator (ROADMAP item 1).

`solve_batch` vmaps Alg. A2 over a leading scenario axis and the per-scenario
solves never talk to each other — the batch is embarrassingly parallel. This
module builds a 1-D ``jax.sharding.Mesh`` over the local devices (axis name
``"scenario"``, the `launch/mesh.py` pattern: functions, never module-level
device state) and the `NamedSharding`s that split that leading axis, so B
scenarios compile into ONE sharded executable solving B/device_count per
device with zero cross-device communication.

Everything works on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the `launch/dryrun.py`
trick), which is how CI exercises the sharded path without an accelerator.

Equivalence guarantee (asserted in `tests/test_distribute.py`): a sharded
`solve_batch(..., mesh=...)` returns the *same hardened assignment* X as the
single-device solve for every scenario — the device split is invisible to
callers; continuous leaves (P, f, rho, trace) agree to float32 round-off.
Non-divisible batches are padded by replicating the tail scenario
(`pad_batch`) and sliced back (`slice_batch`) — exact, because the
per-scenario solves are independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

#: Mesh axis the batch (leading) dimension of stacked scenario pytrees lives on.
SCENARIO_AXIS = "scenario"


def scenario_mesh(devices=None) -> Mesh:
    """1-D mesh over ``devices`` (default: all local devices)."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), (SCENARIO_AXIS,))


def scenario_sharding(mesh: Mesh) -> NamedSharding:
    """Split the leading (scenario) axis across the mesh; trailing axes whole.

    This is the only sharding the batched allocator ever uses: applied to the
    in/out leaves of `sharded_batch_solver`, it partitions `solve_batch` into
    B/mesh.size independent per-device solves with zero cross-device
    communication (scenarios never interact), which is why sharded results
    match single-device results exactly on the hardened X.
    """
    return NamedSharding(mesh, PartitionSpec(SCENARIO_AXIS))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement on the mesh (broadcast weights, accuracy fit)."""
    return NamedSharding(mesh, PartitionSpec())


def round_up(b: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``b``."""
    return -(-b // multiple) * multiple


def pad_batch(tree, to_size: int):
    """Pad every leaf's leading axis to ``to_size`` by replicating the tail.

    The per-scenario solves are independent, so tail replicas are exact
    throwaway work: slice the result back with `slice_batch`. Used to make a
    batch divisible by the mesh size before sharding.
    """

    def leaf(x):
        b = x.shape[0]
        if b == to_size:
            return x
        if b > to_size:
            raise ValueError(f"pad_batch cannot shrink: batch {b} > {to_size}")
        reps = jnp.broadcast_to(x[-1:], (to_size - b,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)

    return jax.tree.map(leaf, tree)


def slice_batch(tree, b: int):
    """Undo `pad_batch`: keep the first ``b`` entries of every leaf."""
    return jax.tree.map(lambda x: x[:b], tree)


def shard_batch(tree, mesh: Mesh):
    """Place a batch-stacked pytree with its leading axis split on the mesh.

    Every data leaf must carry the batch axis (the `stack_params` /
    `stack_weights` contract) with size divisible by ``mesh.size``.
    """
    return jax.device_put(tree, scenario_sharding(mesh))
