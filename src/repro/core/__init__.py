"""FedSem core: the paper's resource-allocation contribution in JAX."""
from .accuracy import AccuracyFn, default_accuracy, fit_power_law, stack_accuracy
from .bits import tree_bits
from .allocator import (
    AllocatorConfig, AllocatorResult, ExtraStart, refine_with_start,
    sharded_batch_solver, sharded_refine_solver, solve, solve_batch,
)
from .channel import sample_params, sample_params_batch, sample_request_stream
from .scoring import batch_objectives, candidate_objectives, scenario_objective
from .distribute import (
    SCENARIO_AXIS, pad_batch, scenario_mesh, scenario_sharding, shard_batch,
    slice_batch,
)
from .types import (
    DEFAULT_BUCKETS, Allocation, ShapeBucket, SystemParams, Weights,
    bucket_for, dbm_to_watt, pad_params, stack_params, stack_weights,
    tree_index, unpad_alloc,
)

__all__ = [
    "AccuracyFn", "default_accuracy", "fit_power_law", "stack_accuracy",
    "tree_bits",
    "AllocatorConfig", "AllocatorResult", "solve", "solve_batch",
    "sharded_batch_solver", "ExtraStart", "refine_with_start",
    "sharded_refine_solver",
    "sample_params", "sample_params_batch", "sample_request_stream",
    "batch_objectives", "candidate_objectives", "scenario_objective",
    "Allocation", "SystemParams", "Weights", "dbm_to_watt",
    "stack_params", "stack_weights", "tree_index",
    "ShapeBucket", "DEFAULT_BUCKETS", "bucket_for", "pad_params", "unpad_alloc",
    "SCENARIO_AXIS", "scenario_mesh", "scenario_sharding", "shard_batch",
    "pad_batch", "slice_batch",
]
