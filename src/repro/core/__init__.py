"""FedSem core: the paper's resource-allocation contribution in JAX."""
from .accuracy import AccuracyFn, default_accuracy, fit_power_law
from .allocator import AllocatorConfig, AllocatorResult, solve
from .channel import sample_params
from .types import Allocation, SystemParams, Weights, dbm_to_watt

__all__ = [
    "AccuracyFn", "default_accuracy", "fit_power_law",
    "AllocatorConfig", "AllocatorResult", "solve",
    "sample_params", "Allocation", "SystemParams", "Weights", "dbm_to_watt",
]
