"""FedSem core: the paper's resource-allocation contribution in JAX."""
from .accuracy import AccuracyFn, default_accuracy, fit_power_law
from .allocator import AllocatorConfig, AllocatorResult, solve, solve_batch
from .channel import sample_params, sample_params_batch
from .types import (
    Allocation, SystemParams, Weights, dbm_to_watt, stack_params, tree_index,
)

__all__ = [
    "AccuracyFn", "default_accuracy", "fit_power_law",
    "AllocatorConfig", "AllocatorResult", "solve", "solve_batch",
    "sample_params", "sample_params_batch",
    "Allocation", "SystemParams", "Weights", "dbm_to_watt",
    "stack_params", "tree_index",
]
