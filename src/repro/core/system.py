"""FedSem system model: OFDMA rates, FL/SemCom energy & delay, objective (P1).

All functions are pure jnp over `SystemParams` / `Allocation` pytrees and are
safe under jit/vmap/grad. Equation numbers reference the paper.
"""
from __future__ import annotations

import jax.numpy as jnp

from .accuracy import AccuracyFn, default_accuracy
from .types import Allocation, SystemParams, Weights

_EPS = 1e-12
_LN2 = 0.6931471805599453


def subcarrier_rate(params: SystemParams, P: jnp.ndarray) -> jnp.ndarray:
    """r_{n,k}(p) = Bbar log2(1 + p g / (N0 Bbar)).  Eq. (1).  (N, K)."""
    snr = P * params.g / params.noise_sc
    return params.bbar * jnp.log1p(snr) / _LN2


def device_rate(params: SystemParams, P: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """r_n = sum_k x_{n,k} r_{n,k}.  Eq. (2).  (N,)."""
    return jnp.sum(X * subcarrier_rate(params, P), axis=-1)


def device_power(P: jnp.ndarray) -> jnp.ndarray:
    """p_n = sum_k p_{n,k}.  Eq. (3)."""
    return jnp.sum(P, axis=-1)


def fl_tx_time(params: SystemParams, r: jnp.ndarray) -> jnp.ndarray:
    """tau_n = D_n / r_n.  Eq. (4)."""
    return params.D / jnp.maximum(r, _EPS)


def fl_tx_energy(p_n: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """E^t_n = p_n tau_n.  Eq. (5)."""
    return p_n * tau


def comp_time(params: SystemParams, f: jnp.ndarray) -> jnp.ndarray:
    """t^c_n = eta c_n d_n / f_n.  Eq. (6)."""
    return params.eta * params.c * params.d / jnp.maximum(f, _EPS)


def comp_energy(params: SystemParams, f: jnp.ndarray) -> jnp.ndarray:
    """E^c_n = xi eta c_n d_n f_n^2.  Eq. (7)."""
    return params.xi * params.eta * params.c * params.d * jnp.square(f)


def semcom_time(params: SystemParams, rho, r: jnp.ndarray) -> jnp.ndarray:
    """T^sc_n = rho C_n / r_n.  Eq. (10)."""
    return rho * params.C / jnp.maximum(r, _EPS)


def semcom_energy(params: SystemParams, rho, p_n, r) -> jnp.ndarray:
    """E^sc_n = p_n rho C_n / r_n.  Eq. (12)."""
    return p_n * semcom_time(params, rho, r)


def t_fl(params: SystemParams, alloc: Allocation) -> jnp.ndarray:
    """T_FL = max_n (tau_n + t^c_n).  Eq. (8)."""
    r = device_rate(params, alloc.P, alloc.X)
    return jnp.max(fl_tx_time(params, r) + comp_time(params, alloc.f))


def energy_breakdown(params: SystemParams, alloc: Allocation):
    """Per-device (E^t, E^c, E^sc) tuple, each (N,)."""
    r = device_rate(params, alloc.P, alloc.X)
    p_n = device_power(alloc.P)
    e_t = fl_tx_energy(p_n, fl_tx_time(params, r))
    e_c = comp_energy(params, alloc.f)
    e_sc = semcom_energy(params, alloc.rho, p_n, r)
    return e_t, e_c, e_sc


def objective(
    params: SystemParams,
    weights: Weights,
    alloc: Allocation,
    accuracy: AccuracyFn | None = None,
) -> jnp.ndarray:
    """P1's objective, eq. (13): k1 Sum E_n + k2 T_FL - k3 Sum A_n(rho)."""
    acc = accuracy or default_accuracy()
    e_t, e_c, e_sc = energy_breakdown(params, alloc)
    total_e = jnp.sum(e_t + e_c + e_sc)
    t = t_fl(params, alloc)
    # sum A_n(rho) over *real* devices only — padded devices (dev_mask = 0,
    # see `pad_params`) already contribute zero energy/delay, and masking here
    # keeps the accuracy reward identical to the exact-shape scenario too
    a = jnp.sum(params.dev_mask * acc.value(alloc.rho))
    return weights.kappa1 * total_e + weights.kappa2 * t - weights.kappa3 * a


def report(params: SystemParams, weights: Weights, alloc: Allocation,
           accuracy: AccuracyFn | None = None) -> dict:
    """Scalar diagnostics used by benchmarks / EXPERIMENTS.md."""
    acc = accuracy or default_accuracy()
    e_t, e_c, e_sc = energy_breakdown(params, alloc)
    r = device_rate(params, alloc.P, alloc.X)
    return {
        "objective": objective(params, weights, alloc, acc),
        "energy_total": jnp.sum(e_t + e_c + e_sc),
        "energy_fl_tx": jnp.sum(e_t),
        "energy_fl_comp": jnp.sum(e_c),
        "energy_semcom": jnp.sum(e_sc),
        "t_fl": t_fl(params, alloc),
        "t_sc_max_dev": jnp.max(semcom_time(params, alloc.rho, r)),
        "accuracy": acc.value(alloc.rho),
        "rho": alloc.rho,
        "min_rate": jnp.min(r),
    }


def feasible(params: SystemParams, alloc: Allocation, tol: float = 1e-4) -> jnp.ndarray:
    """Boolean feasibility of constraints (13a)-(13g) (X treated as binary>=.5)."""
    xb = alloc.X > 0.5
    ok_pow_sc = jnp.all(alloc.P <= jnp.where(xb, params.p_max[:, None], 0.0) * (1 + tol) + _EPS)
    ok_pow = jnp.all(device_power(alloc.P) <= params.p_max * (1 + tol))
    ok_f = jnp.all(alloc.f <= params.f_max * (1 + tol))
    ok_sc = jnp.all(jnp.sum(xb, axis=0) <= 1)
    r = device_rate(params, alloc.P, alloc.X)
    ok_tsc = jnp.all(semcom_time(params, alloc.rho, r) <= params.t_sc_max * (1 + tol))
    ok_rho = (alloc.rho <= 1.0 + tol) & (alloc.rho >= 0.0)
    return ok_pow_sc & ok_pow & ok_f & ok_sc & ok_tsc & ok_rho
