"""Approximate exhaustive search (paper §V-F, Table II).

The paper's toy: N=4 devices, K=5 subcarriers, coarse grids over f, p, rho.
We enumerate all N^K subcarrier assignments exactly, and per assignment sweep
a per-device (f, p, rho) grid. Per-device power is spread equally over the
device's subcarriers (the paper's per-(n,k) grid at 1.5e10 points is not
tractable on one CPU core; reductions documented in benchmarks/table2).

The grid objective evaluation is the compute hot-spot; assignments are
evaluated in *chunks* through the batched
``repro.kernels.fedsem_objective`` evaluator (each chunk row = one subcarrier
assignment on the kernel's scenario axis, its (f, p, rho) grid on the
candidate axis), so the former one-jit-call-per-assignment python loop
becomes a handful of fused (CHUNK, G) kernel launches — Pallas on TPU, the
kernel's jnp oracle elsewhere.
"""
from __future__ import annotations

import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .system import subcarrier_rate
from .types import Allocation, SystemParams, Weights, dbm_to_watt

#: cap on CHUNK * G * N elements per batched evaluation (~8 MB fp32 tiles):
#: bounds peak memory while keeping chunks wide enough to amortise dispatch
_CHUNK_BUDGET = 2_000_000


class ExhaustiveResult(NamedTuple):
    alloc: Allocation
    value: jnp.ndarray
    n_evaluated: int


def solve_exhaustive(
    params: SystemParams,
    weights: Weights,
    f_levels: np.ndarray,
    p_levels_dbm: np.ndarray,
    rho_levels: np.ndarray,
    accuracy_ab=(0.6356, 0.4025),
    chunk: int | None = None,
) -> ExhaustiveResult:
    """Enumerate all N^K assignments; grid-sweep (f, p, rho) per assignment.

    ``chunk`` overrides how many assignments ride one batched kernel call
    (default: sized so a chunk's candidate tile stays ~a few MB).
    """
    from repro.kernels.fedsem_objective import ops

    N, K = params.N, params.K
    assert N**K <= 2_000_000, "exhaustive X enumeration too large"

    f_levels = np.asarray(f_levels, np.float32)
    p_levels = np.asarray(dbm_to_watt(jnp.asarray(p_levels_dbm)), np.float32)
    rho_levels = np.asarray(rho_levels, np.float32)

    # per-device candidate tuples (f, p) — meshgrid over devices
    f_mesh = np.stack(
        np.meshgrid(*([f_levels] * N), indexing="ij"), -1
    ).reshape(-1, N)                                      # (Lf^N, N)
    p_idx = np.stack(
        np.meshgrid(*([np.arange(len(p_levels))] * N), indexing="ij"), -1
    ).reshape(-1, N)                                      # (Lp^N, N)
    p_mesh = p_levels[p_idx]                              # (Lp^N, N)

    A_, B_, Lr = len(f_mesh), len(p_mesh), len(rho_levels)
    G = A_ * B_ * Lr                                      # candidates / assignment
    if chunk is None:
        chunk = int(max(1, min(64, _CHUNK_BUDGET // max(G * N, 1))))

    # candidate grid shared by every assignment (flat index g = (a, b, r)):
    # f repeats over (p, rho), p tiles over f / repeats over rho, rho tiles
    fs = jnp.repeat(jnp.asarray(f_mesh), B_ * Lr, axis=0)             # (G, N)
    ps = jnp.tile(jnp.repeat(jnp.asarray(p_mesh), Lr, axis=0), (A_, 1))
    rho_c = jnp.tile(jnp.asarray(rho_levels), A_ * B_)                # (G,)
    p_idx_j = jnp.asarray(p_idx)
    p_levels_j = jnp.asarray(p_levels)

    @jax.jit
    def eval_chunk(owners, fs, ps, rho_c):
        """owners: (CH, K) int device per subcarrier; fs/ps (G, N) and rho_c
        (G,) are the shared candidate grid (runtime args, NOT closure
        constants — XLA would constant-fold the broadcast (CH, G, N)
        feasibility compares at compile time, which stalls for seconds).
        Returns per-assignment (best value, flat candidate argmin)."""

        def rates(owner):
            X = jnp.zeros((N, K)).at[owner, jnp.arange(K)].set(1.0)
            n_sc = jnp.maximum(jnp.sum(X, axis=-1), 1.0)          # (N,)
            # rate table: (Lp, N) — device rate at total power level p
            P_tab = (p_levels_j[:, None, None] / n_sc[None, :, None]) * X[None]
            r_tab = jnp.sum(X[None] * subcarrier_rate(params, P_tab), axis=-1)
            return r_tab[p_idx_j, jnp.arange(N)[None, :]]          # (B_, N)

        rs = jax.vmap(rates)(owners)                               # (CH, B_, N)
        ch = owners.shape[0]
        r_c = jnp.tile(jnp.repeat(rs, Lr, axis=1), (1, A_, 1))     # (CH, G, N)
        row = lambda v: jnp.broadcast_to(v[None], (ch,) + v.shape)
        obj = ops.objective_grid_batch(
            row(fs), row(ps), r_c, jnp.broadcast_to(rho_c[None], (ch, G)),
            row(params.c), row(params.d), row(params.D), row(params.C),
            row(params.t_sc_max), row(params.f_max),
            float(weights.kappa1), float(weights.kappa2), float(weights.kappa3),
            xi=float(params.xi), eta=float(params.eta),
            accuracy_ab=accuracy_ab,
            # padded scenarios (`pad_params`) score like their exact-shape
            # twin: real device count, masked reductions, masked feasibility
            dev_mask=row(params.dev_mask),
        )                                                          # (CH, G)
        return jnp.min(obj, axis=-1), jnp.argmin(obj, axis=-1)

    owners_np = np.fromiter(
        itertools.chain.from_iterable(itertools.product(range(N), repeat=K)),
        np.int32,
    ).reshape(-1, K)                                               # (N^K, K)
    m = len(owners_np)
    m_pad = -(-m // chunk) * chunk
    owners_pad = np.concatenate(
        [owners_np, np.repeat(owners_np[-1:], m_pad - m, axis=0)]
    )

    best_val = np.inf
    best_owner_i = best_g = -1
    for lo in range(0, m_pad, chunk):
        vals, idxs = jax.block_until_ready(
            eval_chunk(jnp.asarray(owners_pad[lo : lo + chunk]), fs, ps, rho_c)
        )
        vals = np.asarray(vals)
        # padded tail rows replicate the last assignment: harmless duplicates,
        # but keep them out of the argmin bookkeeping
        valid = min(chunk, m - lo)
        i = int(np.argmin(vals[:valid])) if valid > 0 else 0
        if valid > 0 and vals[i] < best_val:
            best_val = float(vals[i])
            best_owner_i = lo + i
            best_g = int(np.asarray(idxs)[i])

    if best_owner_i < 0:
        raise ValueError(
            "solve_exhaustive: every candidate in the grid is infeasible "
            "(all objectives +inf) — the SemCom deadline t_sc_max or f_max "
            "cannot be met at any grid point; widen the f/p/rho levels"
        )
    owner = owners_np[best_owner_i]
    f_c = f_mesh[best_g // (B_ * Lr)]
    p_c = p_mesh[(best_g // Lr) % B_]
    rho_best = float(rho_levels[best_g % Lr])
    X = np.zeros((N, K), np.float32)
    X[owner, np.arange(K)] = 1.0
    n_sc = np.maximum(X.sum(-1), 1.0)
    P = X * (p_c / n_sc)[:, None]
    alloc = Allocation(
        f=jnp.asarray(f_c), P=jnp.asarray(P), X=jnp.asarray(X),
        rho=jnp.float32(rho_best),
    )
    return ExhaustiveResult(
        alloc=alloc, value=jnp.float32(best_val), n_evaluated=m * G
    )
