"""Approximate exhaustive search (paper §V-F, Table II).

The paper's toy: N=4 devices, K=5 subcarriers, coarse grids over f, p, rho.
We enumerate all N^K subcarrier assignments exactly, and per assignment sweep
a per-device (f, p, rho) grid. Per-device power is spread equally over the
device's subcarriers (the paper's per-(n,k) grid at 1.5e10 points is not
tractable on one CPU core; reductions documented in benchmarks/table2).

The grid objective evaluation is the compute hot-spot; it runs through
``repro.kernels.fedsem_objective`` (Pallas kernel with jnp fallback).
"""
from __future__ import annotations

import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .system import subcarrier_rate
from .types import Allocation, SystemParams, Weights, dbm_to_watt


class ExhaustiveResult(NamedTuple):
    alloc: Allocation
    value: jnp.ndarray
    n_evaluated: int


def _grid_eval_fn():
    from repro.kernels.fedsem_objective import ops

    return ops.objective_grid


def solve_exhaustive(
    params: SystemParams,
    weights: Weights,
    f_levels: np.ndarray,
    p_levels_dbm: np.ndarray,
    rho_levels: np.ndarray,
    accuracy_ab=(0.6356, 0.4025),
) -> ExhaustiveResult:
    N, K = params.N, params.K
    assert N**K <= 2_000_000, "exhaustive X enumeration too large"
    objective_grid = _grid_eval_fn()

    f_levels = np.asarray(f_levels, np.float32)
    p_levels = np.asarray(dbm_to_watt(jnp.asarray(p_levels_dbm)), np.float32)
    rho_levels = np.asarray(rho_levels, np.float32)

    # per-device candidate tuples (f, p) — meshgrid over devices
    f_mesh = np.stack(
        np.meshgrid(*([f_levels] * N), indexing="ij"), -1
    ).reshape(-1, N)                                      # (Lf^N, N)
    p_mesh = np.stack(
        np.meshgrid(*([p_levels] * N), indexing="ij"), -1
    ).reshape(-1, N)                                      # (Lp^N, N)

    @jax.jit
    def eval_assignment(owner):
        """owner: (K,) int device per subcarrier -> (best value, argmin info)."""
        X = jnp.zeros((N, K)).at[owner, jnp.arange(K)].set(1.0)
        n_sc = jnp.maximum(jnp.sum(X, axis=-1), 1.0)      # (N,)
        p_levels_j = jnp.asarray(p_levels)
        # rate table: (Lp, N) — device rate when transmitting at level p total
        P_tab = (p_levels_j[:, None, None] / n_sc[None, :, None]) * X[None]
        r_tab = jnp.sum(X[None] * subcarrier_rate(params, P_tab), axis=-1)  # (Lp, N)

        # broadcast candidates: G = Lf^N * Lp^N * Lr
        fs = jnp.asarray(f_mesh)                           # (A, N)
        p_idx = jnp.stack(
            jnp.meshgrid(*([jnp.arange(len(p_levels))] * N), indexing="ij"), -1
        ).reshape(-1, N)                                   # (B, N)
        ps = p_levels_j[p_idx]                             # (B, N)
        rs = r_tab[p_idx, jnp.arange(N)[None, :]]          # (B, N)

        A_, B_ = fs.shape[0], ps.shape[0]
        Lr = len(rho_levels)
        f_c = jnp.repeat(fs, B_ * Lr, axis=0)
        p_c = jnp.tile(jnp.repeat(ps, Lr, axis=0), (A_, 1))
        r_c = jnp.tile(jnp.repeat(rs, Lr, axis=0), (A_, 1))
        rho_c = jnp.tile(jnp.asarray(rho_levels), A_ * B_)

        obj = objective_grid(
            f_c, p_c, r_c, rho_c,
            params.c, params.d, params.D, params.C,
            params.t_sc_max, params.f_max,
            float(params.xi), float(params.eta),
            float(weights.kappa1), float(weights.kappa2), float(weights.kappa3),
            accuracy_ab,
            # padded scenarios (`pad_params`) score like their exact-shape twin:
            # real device count, masked reductions, masked feasibility
            dev_mask=params.dev_mask,
        )
        best = jnp.argmin(obj)
        return obj[best], f_c[best], p_c[best], rho_c[best]

    best_val = np.inf
    best = None
    n_eval = 0
    per_x = len(f_mesh) * len(p_mesh) * len(rho_levels)
    for owner_tuple in itertools.product(range(N), repeat=K):
        owner = jnp.asarray(owner_tuple, jnp.int32)
        val, f_c, p_c, rho_c = eval_assignment(owner)
        n_eval += per_x
        val = float(val)
        if val < best_val:
            best_val = val
            best = (np.asarray(owner_tuple), np.asarray(f_c), np.asarray(p_c), float(rho_c))

    owner, f_c, p_c, rho_c = best
    X = np.zeros((N, K), np.float32)
    X[owner, np.arange(K)] = 1.0
    n_sc = np.maximum(X.sum(-1), 1.0)
    P = X * (p_c / n_sc)[:, None]
    alloc = Allocation(
        f=jnp.asarray(f_c), P=jnp.asarray(P), X=jnp.asarray(X), rho=jnp.float32(rho_c)
    )
    return ExhaustiveResult(alloc=alloc, value=jnp.float32(best_val), n_evaluated=n_eval)
