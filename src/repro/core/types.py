"""Pytree dataclasses for the FedSem wireless system (paper Table I).

Everything is a registered JAX pytree so the whole allocator jits and vmaps
over batches of channel realisations.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# unit helpers
# ---------------------------------------------------------------------------


def dbm_to_watt(dbm):
    return 10.0 ** ((jnp.asarray(dbm, jnp.float32) - 30.0) / 10.0)


def db_to_linear(db):
    return 10.0 ** (jnp.asarray(db, jnp.float32) / 10.0)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["g", "c", "d", "D", "C", "p_max", "f_max", "t_sc_max"],
    meta_fields=["N", "K", "B", "N0", "xi", "eta", "q"],
)
@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static description of one FedSem wireless scenario.

    Shapes: ``g`` is (N, K) channel gain (linear); ``c, d, D, C, p_max,
    f_max, t_sc_max`` are (N,).

    Meta (python scalars, hashable for jit):
      N devices, K subcarriers, B total bandwidth [Hz], N0 noise PSD [W/Hz],
      xi effective switched capacitance, eta local iterations,
      q binary-tightening exponent of (35a).
    """

    g: jax.Array
    c: jax.Array        # CPU cycles / sample
    d: jax.Array        # samples per device
    D: jax.Array        # FL upload size [bits]
    C: jax.Array        # total SemCom payload L * C_{n,l} [bits]
    p_max: jax.Array    # [W]
    f_max: jax.Array    # [Hz]
    t_sc_max: jax.Array  # SemCom deadline [s]
    N: int = 10
    K: int = 50
    B: float = 20e6
    N0: float = 10.0 ** ((-174.0 - 30.0) / 10.0)
    xi: float = 1e-28
    eta: int = 10
    q: int = 2

    @property
    def bbar(self) -> float:
        """Per-subcarrier bandwidth B/K [Hz]."""
        return self.B / self.K

    @property
    def noise_sc(self) -> float:
        """Noise power per subcarrier N0 * Bbar [W]."""
        return self.N0 * self.bbar


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["kappa1", "kappa2", "kappa3"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class Weights:
    """Objective weights (kappa1 [1/J], kappa2 [1/s], kappa3 [unitless])."""

    kappa1: jax.Array
    kappa2: jax.Array
    kappa3: jax.Array

    @staticmethod
    def ones() -> "Weights":
        one = jnp.float32(1.0)
        return Weights(one, one, one)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["f", "P", "X", "rho"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class Allocation:
    """Decision variables of problem P1.

    f: (N,) CPU frequency [Hz]; P: (N, K) transmit power [W];
    X: (N, K) subcarrier indicator (relaxed in [0,1] inside the solver,
    ~binary at the end); rho: scalar compression rate in (0, 1].
    """

    f: jax.Array
    P: jax.Array
    X: jax.Array
    rho: jax.Array
