"""Pytree dataclasses for the FedSem wireless system (paper Table I).

Everything is a registered JAX pytree so the whole allocator jits and vmaps
over batches of channel realisations.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# unit helpers
# ---------------------------------------------------------------------------


def dbm_to_watt(dbm):
    return 10.0 ** ((jnp.asarray(dbm, jnp.float32) - 30.0) / 10.0)


def db_to_linear(db):
    return 10.0 ** (jnp.asarray(db, jnp.float32) / 10.0)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["g", "c", "d", "D", "C", "p_max", "f_max", "t_sc_max"],
    meta_fields=["N", "K", "B", "N0", "xi", "eta", "q"],
)
@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static description of one FedSem wireless scenario.

    Shapes: ``g`` is (N, K) channel gain (linear); ``c, d, D, C, p_max,
    f_max, t_sc_max`` are (N,).

    Meta (python scalars, hashable for jit):
      N devices, K subcarriers, B total bandwidth [Hz], N0 noise PSD [W/Hz],
      xi effective switched capacitance, eta local iterations,
      q binary-tightening exponent of (35a).
    """

    g: jax.Array
    c: jax.Array        # CPU cycles / sample
    d: jax.Array        # samples per device
    D: jax.Array        # FL upload size [bits]
    C: jax.Array        # total SemCom payload L * C_{n,l} [bits]
    p_max: jax.Array    # [W]
    f_max: jax.Array    # [Hz]
    t_sc_max: jax.Array  # SemCom deadline [s]
    N: int = 10
    K: int = 50
    B: float = 20e6
    N0: float = 10.0 ** ((-174.0 - 30.0) / 10.0)
    xi: float = 1e-28
    eta: int = 10
    q: int = 2

    def __post_init__(self):
        # Constraint (13d) allocates each subcarrier to at most one device and
        # the allocator guarantees >= 1 subcarrier per device after hardening
        # (`harden_x`) — both are only satisfiable when K >= N. Validate here
        # (meta fields are python ints, so this is jit/vmap-safe) instead of
        # letting `equal_start` silently leave devices with no subcarriers.
        if self.K < self.N:
            raise ValueError(
                f"SystemParams requires K >= N (each of the N={self.N} devices "
                f"needs at least one of the K={self.K} subcarriers to satisfy "
                "the rate floor); got K < N"
            )

    @property
    def bbar(self) -> float:
        """Per-subcarrier bandwidth B/K [Hz]."""
        return self.B / self.K

    @property
    def noise_sc(self) -> float:
        """Noise power per subcarrier N0 * Bbar [W]."""
        return self.N0 * self.bbar


def stack_params(params_list) -> "SystemParams":
    """Stack SystemParams pytrees over a new leading batch axis.

    All scenarios must share the meta fields (N, K, B, N0, xi, eta, q) —
    those are static under jit, so a batch is one compiled program. Shapes
    become ``g: (B, N, K)`` and ``(B, N)`` for the per-device vectors.
    """
    params_list = list(params_list)
    if not params_list:
        raise ValueError("stack_params needs at least one SystemParams")
    ref = params_list[0]
    meta = ("N", "K", "B", "N0", "xi", "eta", "q")
    for i, p in enumerate(params_list[1:], start=1):
        bad = [f for f in meta if getattr(p, f) != getattr(ref, f)]
        if bad:
            raise ValueError(
                f"stack_params: scenario {i} differs from scenario 0 in static "
                f"field(s) {bad}; batched solves require identical meta"
            )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def tree_index(tree, i):
    """Select scenario ``i`` from a batch-stacked pytree (inverse of stack)."""
    return jax.tree.map(lambda x: x[i], tree)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["kappa1", "kappa2", "kappa3"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class Weights:
    """Objective weights (kappa1 [1/J], kappa2 [1/s], kappa3 [unitless])."""

    kappa1: jax.Array
    kappa2: jax.Array
    kappa3: jax.Array

    @staticmethod
    def ones() -> "Weights":
        one = jnp.float32(1.0)
        return Weights(one, one, one)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["f", "P", "X", "rho"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class Allocation:
    """Decision variables of problem P1.

    f: (N,) CPU frequency [Hz]; P: (N, K) transmit power [W];
    X: (N, K) subcarrier indicator (relaxed in [0,1] inside the solver,
    ~binary at the end); rho: scalar compression rate in (0, 1].
    """

    f: jax.Array
    P: jax.Array
    X: jax.Array
    rho: jax.Array
