"""Pytree dataclasses for the FedSem wireless system (paper Table I).

Everything is a registered JAX pytree so the whole allocator jits and vmaps
over batches of channel realisations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# unit helpers
# ---------------------------------------------------------------------------


def dbm_to_watt(dbm):
    return 10.0 ** ((jnp.asarray(dbm, jnp.float32) - 30.0) / 10.0)


def db_to_linear(db):
    return 10.0 ** (jnp.asarray(db, jnp.float32) / 10.0)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "g", "c", "d", "D", "C", "p_max", "f_max", "t_sc_max",
        "dev_mask", "sc_mask",
    ],
    meta_fields=["N", "K", "B", "N0", "xi", "eta", "q"],
)
@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static description of one FedSem wireless scenario.

    Shapes: ``g`` is (N, K) channel gain (linear); ``c, d, D, C, p_max,
    f_max, t_sc_max`` are (N,).

    ``dev_mask`` (N,) / ``sc_mask`` (K,) are {0,1} validity masks used by the
    serving layer's shape buckets (`pad_params`): real devices/subcarriers
    occupy the *leading* indices, padded ones carry mask 0 and must not
    perturb the objective or the hardened allocation. Defaults to all-ones
    (every entry real), so the masks are invisible outside padded solves.

    Meta (python scalars, hashable for jit):
      N devices, K subcarriers, B total bandwidth [Hz], N0 noise PSD [W/Hz],
      xi effective switched capacitance, eta local iterations,
      q binary-tightening exponent of (35a).
    """

    g: jax.Array
    c: jax.Array        # CPU cycles / sample
    d: jax.Array        # samples per device
    D: jax.Array        # FL upload size [bits]
    C: jax.Array        # total SemCom payload L * C_{n,l} [bits]
    p_max: jax.Array    # [W]
    f_max: jax.Array    # [Hz]
    t_sc_max: jax.Array  # SemCom deadline [s]
    dev_mask: jax.Array | None = None   # (N,) 1 = real device, 0 = padding
    sc_mask: jax.Array | None = None    # (K,) 1 = real subcarrier, 0 = padding
    N: int = 10
    K: int = 50
    B: float = 20e6
    N0: float = 10.0 ** ((-174.0 - 30.0) / 10.0)
    xi: float = 1e-28
    eta: int = 10
    q: int = 2

    def __post_init__(self):
        if self.dev_mask is None:
            object.__setattr__(self, "dev_mask", jnp.ones((self.N,), jnp.float32))
        if self.sc_mask is None:
            object.__setattr__(self, "sc_mask", jnp.ones((self.K,), jnp.float32))
        # Constraint (13d) allocates each subcarrier to at most one device and
        # the allocator guarantees >= 1 subcarrier per device after hardening
        # (`harden_x`) — both are only satisfiable when K >= N. Validate here
        # (meta fields are python ints, so this is jit/vmap-safe) instead of
        # letting `equal_start` silently leave devices with no subcarriers.
        if self.K < self.N:
            raise ValueError(
                f"SystemParams requires K >= N (each of the N={self.N} devices "
                f"needs at least one of the K={self.K} subcarriers to satisfy "
                "the rate floor); got K < N"
            )

    @property
    def bbar(self) -> float:
        """Per-subcarrier bandwidth B/K [Hz]."""
        return self.B / self.K

    @property
    def noise_sc(self) -> float:
        """Noise power per subcarrier N0 * Bbar [W]."""
        return self.N0 * self.bbar


def stack_params(params_list) -> "SystemParams":
    """Stack SystemParams pytrees over a new leading batch axis.

    All scenarios must share the meta fields (N, K, B, N0, xi, eta, q) —
    those are static under jit, so a batch is one compiled program. Shapes
    become ``g: (B, N, K)`` and ``(B, N)`` for the per-device vectors.
    """
    params_list = list(params_list)
    if not params_list:
        raise ValueError("stack_params needs at least one SystemParams")
    ref = params_list[0]
    meta = ("N", "K", "B", "N0", "xi", "eta", "q")
    for i, p in enumerate(params_list[1:], start=1):
        bad = [f for f in meta if getattr(p, f) != getattr(ref, f)]
        if bad:
            raise ValueError(
                f"stack_params: scenario {i} differs from scenario 0 in static "
                f"field(s) {bad}; batched solves require identical meta"
            )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def tree_index(tree, i):
    """Select scenario ``i`` from a batch-stacked pytree (inverse of stack)."""
    return jax.tree.map(lambda x: x[i], tree)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["kappa1", "kappa2", "kappa3"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class Weights:
    """Objective weights (kappa1 [1/J], kappa2 [1/s], kappa3 [unitless])."""

    kappa1: jax.Array
    kappa2: jax.Array
    kappa3: jax.Array

    @staticmethod
    def ones() -> "Weights":
        one = jnp.float32(1.0)
        return Weights(one, one, one)


def stack_weights(weights_list) -> "Weights":
    """Stack per-scenario `Weights` over a new leading batch axis.

    The result feeds ``solve_batch(..., weights_batched=True)`` (sibling of
    `stack_params` for the weights pytree).
    """
    weights_list = list(weights_list)
    if not weights_list:
        raise ValueError("stack_weights needs at least one Weights")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *weights_list)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["f", "P", "X", "rho"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class Allocation:
    """Decision variables of problem P1.

    f: (N,) CPU frequency [Hz]; P: (N, K) transmit power [W];
    X: (N, K) subcarrier indicator (relaxed in [0,1] inside the solver,
    ~binary at the end); rho: scalar compression rate in (0, 1].
    """

    f: jax.Array
    P: jax.Array
    X: jax.Array
    rho: jax.Array


# ---------------------------------------------------------------------------
# shape buckets — the serving layer's padding contract
# ---------------------------------------------------------------------------


class ShapeBucket(NamedTuple):
    """Canonical padded (N, K) shape: every scenario padded into the same
    bucket shares one compiled solver program (the serving layer's unit of
    batching). Buckets must satisfy K >= N (same constraint as the scenarios
    they hold).

    Equivalence guarantee (asserted in `tests/test_serve_alloc.py`): solving
    a `pad_params`-padded scenario yields the same hardened assignment as
    solving the exact-shape scenario — padding affects shapes, never answers
    (see `pad_params` for the mask/bandwidth invariants that make this hold).
    """

    N: int
    K: int

    @property
    def area(self) -> int:
        """Padded problem area N*K — the cost proxy the serving layer's
        bucket ladders minimise (solve time scales with the padded shape,
        not the real one)."""
        return self.N * self.K

    def fits(self, n: int, k: int) -> bool:
        """Whether an (n, k) scenario can pad into this bucket."""
        return self.N >= n and self.K >= k


#: Default bucket ladder for the serving layer: a coarse geometric grid so a
#: handful of compiled programs covers everything from toy scenarios to the
#: paper's (10, 50) and beyond. ~2x area steps keep worst-case padding waste
#: bounded while keeping the executable cache small. `repro.serve.ladder`
#: learns a replacement ladder fitted to an observed shape mix.
DEFAULT_BUCKETS = (
    ShapeBucket(4, 8),
    ShapeBucket(4, 16),
    ShapeBucket(8, 16),
    ShapeBucket(8, 32),
    ShapeBucket(16, 64),
    ShapeBucket(32, 128),
    ShapeBucket(64, 256),
)


def bucket_for(n: int, k: int, buckets=DEFAULT_BUCKETS) -> ShapeBucket:
    """Smallest bucket (by padded area N*K) that fits an (n, k) scenario."""
    fits = [b for b in buckets if b.fits(n, k)]
    if not fits:
        raise ValueError(
            f"no bucket in {tuple(buckets)} fits a scenario with N={n}, K={k}; "
            "extend the bucket ladder"
        )
    return min(fits, key=lambda b: (b.area, b.N))


def pad_params(params: SystemParams, n_pad: int, k_pad: int | None = None) -> SystemParams:
    """Pad a scenario to a canonical (n_pad, k_pad) bucket with validity masks.

    Accepts ``pad_params(params, bucket)`` or ``pad_params(params, N, K)``.
    Real devices/subcarriers stay at the leading indices. Padded entries are
    inert by construction: zero channel gain, zero data/payload (``d = D =
    C = 0``) so every energy/delay term vanishes, and ``dev_mask``/``sc_mask``
    zero so the mask-aware pieces of the solver (accuracy sums, warm starts,
    `harden_x`, the PGD softmax) ignore them. ``B`` is rescaled so the
    per-subcarrier bandwidth ``bbar = B/K`` — the only way bandwidth enters
    the rate math — is preserved exactly; a padded solve therefore matches
    the exact-shape solve on the real block (asserted in tests).
    """
    if k_pad is None:
        n_pad, k_pad = n_pad  # a ShapeBucket / (N, K) tuple
    if n_pad < params.N or k_pad < params.K:
        raise ValueError(
            f"pad_params cannot shrink: scenario is (N={params.N}, K={params.K}), "
            f"requested bucket ({n_pad}, {k_pad})"
        )
    if n_pad == params.N and k_pad == params.K:
        return params
    dn, dk = n_pad - params.N, k_pad - params.K

    def pad_n(x, fill=0.0):
        return jnp.pad(x, (0, dn), constant_values=fill)

    return SystemParams(
        g=jnp.pad(params.g, ((0, dn), (0, dk))),
        c=pad_n(params.c, 1.0),          # value irrelevant: d = 0 zeroes comp terms
        d=pad_n(params.d),
        D=pad_n(params.D),
        C=pad_n(params.C),
        p_max=pad_n(params.p_max, 1.0),  # positive: avoids 0-division in solvers
        f_max=pad_n(params.f_max, 1.0),
        t_sc_max=pad_n(params.t_sc_max, 1.0),
        dev_mask=pad_n(params.dev_mask),
        sc_mask=jnp.pad(params.sc_mask, (0, dk)),
        N=n_pad,
        K=k_pad,
        B=params.bbar * k_pad,           # preserve bbar = B/K exactly
        N0=params.N0,
        xi=params.xi,
        eta=params.eta,
        q=params.q,
    )


def unpad_alloc(alloc: Allocation, n: int, k: int) -> Allocation:
    """Slice the real (n, k) block back out of a padded `Allocation`.

    Works on batched allocations too (slices the trailing device/subcarrier
    axes, leaves leading batch axes alone).
    """
    return Allocation(
        f=alloc.f[..., :n],
        P=alloc.P[..., :n, :k],
        X=alloc.X[..., :n, :k],
        rho=alloc.rho,
    )
