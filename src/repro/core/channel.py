"""Deprecated shims over the scenario registry (`repro.scenarios`).

The Section-V i.i.d. Rayleigh sampler that used to live here is now the
``iid_rayleigh`` family in `repro.scenarios.iid_rayleigh` — same random ops,
same key splits, bit-identical draws. These wrappers keep every existing
call site (`repro.core.sample_params` et al.) working; new code should
resolve a family by name instead:

    from repro.scenarios import get_family
    params = get_family("iid_rayleigh").sample(key, N=10, K=50)

Imports of `repro.scenarios` are deferred into the function bodies because
the scenarios package itself imports `repro.core.types` — a module-level
import here would cycle through `repro.core.__init__`.
"""
from __future__ import annotations

import jax

from .types import SystemParams


def sample_params(key: jax.Array, **kwargs) -> SystemParams:
    """Deprecated: use ``get_family("iid_rayleigh").sample``."""
    from repro.scenarios import get_family

    return get_family("iid_rayleigh").sample(key, **kwargs)


def sample_params_batch(key: jax.Array, batch: int, **kwargs) -> SystemParams:
    """Deprecated: use ``get_family("iid_rayleigh").sample_batch``."""
    from repro.scenarios import get_family

    return get_family("iid_rayleigh").sample_batch(key, batch, **kwargs)


def sample_request_stream(key: jax.Array, n_requests: int, **kwargs) -> list:
    """Deprecated: use ``get_family("iid_rayleigh").stream``."""
    from repro.scenarios import get_family

    return get_family("iid_rayleigh").stream(key, n_requests, **kwargs)
