"""Channel / scenario sampling for FedSem (paper Section V defaults).

Path loss 128.1 + 37.6 log10(dist_km) dB with 8 dB log-normal shadowing,
devices uniform in a 500 m disc, N0 = -174 dBm/Hz, B = 20 MHz, K = 50.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import SystemParams, dbm_to_watt


def sample_params(
    key: jax.Array,
    *,
    N: int = 10,
    K: int = 50,
    B: float = 20e6,
    radius_m: float = 500.0,
    shadowing_db: float = 8.0,
    p_max_dbm: float = 20.0,
    f_max_hz: float = 2e9,
    eta: int = 10,
    d_samples: float = 500.0,
    c_lo: float = 1e4,
    c_hi: float = 3e4,
    D_bits: float = 2.81e4,
    C_round_bits: float = 4.15e6,
    L_rounds: int = 10,
    t_sc_max: float = 20.0,
    q: int = 2,
) -> SystemParams:
    """Draw one scenario with the paper's Table-I defaults."""
    k_pos, k_shadow, k_fade, k_c = jax.random.split(key, 4)

    # uniform in a disc => r ~ sqrt(U) * radius
    u = jax.random.uniform(k_pos, (N,), minval=1e-3)
    dist_km = jnp.sqrt(u) * radius_m / 1000.0
    pl_db = 128.1 + 37.6 * jnp.log10(dist_km)
    shadow = shadowing_db * jax.random.normal(k_shadow, (N,))
    # small-scale Rayleigh fading per subcarrier (block fading in slot t)
    ray = jax.random.exponential(k_fade, (N, K))
    gain_lin = 10.0 ** (-(pl_db + shadow)[:, None] / 10.0) * ray

    c = jax.random.uniform(k_c, (N,), minval=c_lo, maxval=c_hi)

    ones = jnp.ones((N,), jnp.float32)
    return SystemParams(
        g=gain_lin.astype(jnp.float32),
        c=c.astype(jnp.float32),
        d=d_samples * ones,
        D=D_bits * ones,
        C=(C_round_bits * L_rounds) * ones,
        p_max=dbm_to_watt(p_max_dbm) * ones,
        f_max=f_max_hz * ones,
        t_sc_max=t_sc_max * ones,
        N=N,
        K=K,
        B=B,
        q=q,
        eta=eta,
    )


def sample_params_batch(key: jax.Array, batch: int, **kwargs) -> SystemParams:
    """Draw ``batch`` i.i.d. scenarios stacked on a leading axis.

    Same per-scenario defaults as `sample_params`; the result feeds
    `repro.core.solve_batch` directly (``g`` has shape (batch, N, K)).
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: sample_params(k, **kwargs))(keys)


def sample_request_stream(
    key: jax.Array,
    n_requests: int,
    *,
    sizes=((3, 8), (4, 12), (6, 16)),
    bbar: float = 20e6 / 50,
    **kwargs,
) -> list:
    """Draw a heterogeneous scenario stream for the serving layer.

    Each request picks a uniform (N, K) from ``sizes`` and shares the same
    per-subcarrier bandwidth ``bbar`` (total bandwidth B = bbar * K scales
    with K). Sharing bbar is what lets different-size requests pad into the
    same `ShapeBucket` and batch through one compiled solve — bbar is the
    only way bandwidth enters the rate math, and `pad_params` preserves it.
    Returns a list of exact-shape `SystemParams` (the service pads them).
    """
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    out = []
    for i in range(n_requests):
        k_size, k_params = jax.random.split(jax.random.fold_in(key, i))
        n, k = sizes[int(jax.random.randint(k_size, (), 0, len(sizes)))]
        out.append(sample_params(k_params, N=n, K=k, B=bbar * k, **kwargs))
    return out
