"""Alg. A2 — the FedSem resource-allocation algorithm (paper §IV-D).

Alternates:
  Step 1: given (P, X), solve P3(f, rho, T) in closed form (Theorem 1);
  Step 2: given (f, rho, T), solve P4 -> P5 for (P, X) — either the
          paper-faithful SCA/KKT path (`inner="sca"`, Alg. A1) or the
          PGD reference solver (`inner="pgd"`, DESIGN.md §8 cross-check);
until |s^(i) - s^(i-1)| <= eps or J_max (we run a fixed J_max scan and return
the trace; convergence is asserted from the trace in tests).

Afterwards X is hardened to binary (every subcarrier to its argmax device,
every device guaranteed >= 1 subcarrier), powers are re-solved given the
binary X, and (f, rho) are re-derived — a beyond-paper robustness step that
guarantees the reported allocation is feasible for the *original* P1.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .accuracy import AccuracyFn, default_accuracy
from .p3 import solve_p3
from .p5 import P5Config, r_min, solve_p5
from .pgd import PGDConfig, power_given_x, solve_p4_pgd
from .scoring import candidate_objectives, scenario_objective
from .system import objective
from .types import Allocation, SystemParams, Weights


class ExtraStart(NamedTuple):
    """Optional warm-start candidate(s) per scenario (a pytree).

    ``f``/``P``/``X`` are a prior solution at the scenario's (padded) shape —
    e.g. a `repro.serve.warmstart` cache hit or the previous FL round's
    allocation. ``valid`` is a {0, 1} float: scenarios with ``valid == 0``
    carry placeholder arrays and the candidate is excluded from selection
    (its objective is forced to +inf), so a batch can mix hits and misses.
    Batched use stacks a leading B axis on every leaf.

    A CANDIDATE axis may additionally precede the per-scenario shapes
    (``valid``: (C,) single-scenario, (B, C) batched): every candidate is run
    through the same Alg. A2 refine and competes in the same argmin — the
    top-k warm-start path (`repro.serve.warmstart.WarmStartCache.lookup`).
    Candidate-less shapes (scalar / (B,) ``valid``) stay the single-candidate
    program, bit-for-bit.
    """

    f: jax.Array    # (N,) / (B, N) — or (C, N) / (B, C, N)
    P: jax.Array    # (N, K) / (B, N, K) — or (C, N, K) / (B, C, N, K)
    X: jax.Array    # like P
    valid: jax.Array  # scalar / (B,) — or (C,) / (B, C) — in {0., 1.}


class AllocatorConfig(NamedTuple):
    outer_iters: int = 6           # J_max of Alg. A2
    inner: str = "sca"             # "sca" (Alg. A1) | "pgd" (reference) |
                                   # "auto" (run both, keep the better)
    p5: P5Config = P5Config()
    pgd: PGDConfig = PGDConfig()
    #: route objective scoring (multi-start selection, the per-iteration
    #: trace) through the batched `kernels/fedsem_objective` evaluator:
    #: Pallas on TPU, the kernel's fused jnp oracle elsewhere (`core.scoring`
    #: auto-fallback, so CPU and sharded ``mesh=`` solves work unchanged).
    #: False keeps the plain per-candidate `system.objective` path.
    use_kernel_objective: bool = True


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["alloc", "trace"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class AllocatorResult:
    alloc: Allocation
    trace: jax.Array  # objective s^(i) per outer iteration


def equal_start(params: SystemParams):
    """Round-robin X, per-subcarrier power Pmax/|K_n|, f = fmax/2 (warm start).

    Mask-aware: real subcarriers are round-robined over the *real* devices
    (padded entries get nothing), so a padded scenario starts from exactly the
    same assignment as its exact-shape twin.
    """
    k_idx = jnp.arange(params.K)
    n_real = jnp.maximum(jnp.sum(params.dev_mask), 1.0).astype(jnp.int32)
    owner = k_idx % n_real
    X = jnp.zeros((params.N, params.K)).at[owner, k_idx].set(params.sc_mask)
    n_sc = jnp.sum(X, axis=-1, keepdims=True)
    P = X * params.p_max[:, None] / jnp.maximum(n_sc, 1.0)
    f = params.f_max * 0.5
    return f, P, X


def low_power_start(params: SystemParams, margin: float = 1.5):
    """Round-robin X, powers sized to just clear the SemCom rate floor.

    The alternating P3/P4 decomposition has init-dependent fixed points: from
    an equal-power start, Theorem 1 picks f so every uncapped device is
    exactly tight on T, which pins r_min at the *current* rate and blocks any
    power reduction. Starting near the SemCom floor r = C/Tsc_max (the true
    binding rate for the paper's defaults, where E_sc dominates) lets the
    alternation settle at the low-energy fixed point. Multi-start over both
    (paper leaves "the initial feasible solution" unspecified).
    """
    f, _, X = equal_start(params)
    n_sc = jnp.maximum(jnp.sum(X, axis=-1), 1.0)
    target = margin * params.C / params.t_sc_max             # rho=1 worst case
    per_sc = target / n_sc                                   # rate per subcarrier
    snr = jnp.exp2(per_sc / params.bbar) - 1.0
    P = X * (snr[:, None] * params.noise_sc / jnp.maximum(params.g, 1e-18))
    # stay feasible: respect the per-device power budget
    scale = jnp.minimum(1.0, params.p_max / jnp.maximum(jnp.sum(P, -1), 1e-12))
    P = P * scale[:, None]
    return f, P, X


def full_payload_start(
    params: SystemParams, weights: Weights, pgd_cfg: PGDConfig = PGDConfig()
):
    """(P, X) pre-optimised by PGD at rho = 1 (full SemCom payload).

    The alternation's fixed point is init-dependent (see `low_power_start`):
    both existing starts can settle at rho < 1, trading accuracy for energy,
    even when the accuracy weight makes rho ~ 1 optimal. Pre-optimising
    (P, X) against the full payload with the rho = 1 rate floor — exactly the
    communication-only subproblem — gives Alg. A2 a start whose Theorem-1
    step keeps rho high, so the multi-start argmin dominates the
    comm-opt-only baseline by construction (same (P, X) engine, plus the
    closed-form optimal (f, rho, T) on top).
    """
    f, P, X = equal_start(params)
    payload = params.D + params.C                       # rho = 1
    rmin = params.C / params.t_sc_max                   # SemCom deadline floor
    P, X = solve_p4_pgd(params, weights.kappa1, payload, rmin, P, X, pgd_cfg)
    return f, P, X


def repair_rate_floor(params: SystemParams, P, X, rmin, iters: int = 30):
    """Per-device multiplicative power rescale so r_n >= rmin_n (bisection).

    The inner solvers treat the rate floor with multipliers/penalties and can
    exit slightly infeasible; left unrepaired the violation compounds across
    Alg. A2 iterations (rho_max = Tsc_max r / C collapses). Rates increase
    monotonically in a per-device power scale, so a bisection on the scale
    restores feasibility; devices that cannot reach rmin even at Pmax are
    clamped to their budget.
    """
    from .system import device_rate

    p_tot = jnp.maximum(jnp.sum(P, -1), 1e-12)
    s_cap = params.p_max / p_tot                       # max admissible scale

    def rate_at(s):
        return device_rate(params, P * s[:, None], X)

    need = rate_at(jnp.ones_like(p_tot)) < rmin
    lo = jnp.ones_like(p_tot)
    hi = jnp.maximum(s_cap, 1.0)

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        ok = rate_at(mid) >= rmin
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    s = jnp.where(need, jnp.minimum(hi, s_cap), 1.0)
    return P * s[:, None]


def harden_x(X: jnp.ndarray, N: int, K: int, dev_mask=None, sc_mask=None) -> jnp.ndarray:
    """Binary X: argmax per subcarrier, then guarantee >=1 subcarrier/device.

    With masks (padded scenarios, see `pad_params`): padded devices never win
    or steal a subcarrier, padded subcarriers stay unassigned, and ownership
    counts / donor checks consider real subcarriers only — so the real block
    of the hardened assignment is identical to hardening the exact-shape
    scenario.
    """
    if dev_mask is None:
        dev_mask = jnp.ones((N,), X.dtype)
    if sc_mask is None:
        sc_mask = jnp.ones((K,), X.dtype)
    assign = jnp.argmax(jnp.where(dev_mask[:, None] > 0.0, X, -jnp.inf), axis=0)

    def fix_device(n, assign):
        counts = jnp.zeros((N,), X.dtype).at[assign].add(sc_mask)  # real subcarriers
        need = (counts[n] < 0.5) & (dev_mask[n] > 0.0)
        donor_ok = (counts[assign] > 1.5) & (sc_mask > 0.0)  # only steal real sc from the rich
        score = jnp.where(donor_ok, X[n], -jnp.inf)
        k_star = jnp.argmax(score)
        return jnp.where(need, assign.at[k_star].set(n), assign)

    assign = jax.lax.fori_loop(0, N, fix_device, assign)
    return jnp.zeros((N, K)).at[assign, jnp.arange(K)].set(sc_mask)


def solve(
    params: SystemParams,
    weights: Weights,
    cfg: AllocatorConfig = AllocatorConfig(),
    accuracy: AccuracyFn | None = None,
    extra_start: ExtraStart | None = None,
) -> AllocatorResult:
    """Alg. A2 with multi-start (equal + low-power + full-payload inits),
    best kept.

    inner="auto" additionally races the paper-faithful SCA path against the
    PGD cross-check solver and keeps the better allocation. With
    ``cfg.use_kernel_objective`` (default) the multi-start selection and the
    per-iteration trace score through the batched `kernels/fedsem_objective`
    evaluator (`core.scoring`); scores agree with `system.objective` to
    float32 round-off, so the hardened result is unchanged.

    ``extra_start`` optionally adds one more multi-start candidate — a prior
    solution (warm start) run through the same Alg. A2 pipeline and competing
    in the same best-of selection (see `refine_with_start` for the dominance
    and cold-equivalence guarantees). ``None`` leaves this function
    bit-for-bit identical to the pre-warm-start solver.
    """
    acc = accuracy or default_accuracy()
    base = _solve_multi_start(params, weights, cfg, acc)
    if extra_start is None:
        return base
    return refine_with_start(params, weights, cfg, acc, extra_start, base)


def _solve_multi_start(
    params: SystemParams, weights: Weights, cfg: AllocatorConfig, acc: AccuracyFn
) -> AllocatorResult:
    """The cold multi-start solve (the original `solve` body, unchanged)."""
    inners = ("sca", "pgd") if cfg.inner == "auto" else (cfg.inner,)
    starts = (
        equal_start(params),
        low_power_start(params),
        full_payload_start(params, weights, cfg.pgd),
    )
    results = [
        _solve_from(params, weights, cfg._replace(inner=inner), acc, start)
        for inner in inners
        for start in starts
    ]
    if cfg.use_kernel_objective:
        # one fused batched-kernel call scores every start (G = #candidates);
        # under solve_batch's vmap this batches further into (B, G)
        cand = jax.tree.map(lambda *xs: jnp.stack(xs), *[r.alloc for r in results])
        objs = candidate_objectives(params, weights, cand, acc)
    else:
        objs = jnp.stack([objective(params, weights, r.alloc, acc) for r in results])
    best = jnp.argmin(objs)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *results)
    return jax.tree.map(lambda x: x[best], stacked)


def sanitize_start(params: SystemParams, extra: ExtraStart):
    """Clamp an externally supplied (f, P, X) into the solver's domain.

    Warm starts come from outside the solver (a cache, a previous FL round —
    possibly for a *different* scenario under the same signature), so nothing
    about them can be trusted: non-finite entries become benign values, f is
    clipped into (0, f_max], P into [0, p_max] per entry, X into [0, 1], and
    masked (padded) rows/columns are zeroed so a cached exact-shape entry
    padded into a bucket stays inert exactly like the built-in starts. A
    degenerate start (e.g. a device with no subcarrier) may still yield an
    infinite objective downstream — `refine_with_start` masks those out of
    the selection, so garbage can never win, only lose.
    """
    f = jnp.nan_to_num(extra.f, nan=0.0, posinf=0.0, neginf=0.0)
    f = jnp.clip(f, 1e-6 * params.f_max, params.f_max)
    P = jnp.nan_to_num(extra.P, nan=0.0, posinf=0.0, neginf=0.0)
    P = jnp.clip(P, 0.0, params.p_max[:, None])
    X = jnp.nan_to_num(extra.X, nan=0.0, posinf=0.0, neginf=0.0)
    X = jnp.clip(X, 0.0, 1.0)
    live = params.dev_mask[:, None] * params.sc_mask[None, :]
    return f, P * live, X * live


def refine_with_start(
    params: SystemParams,
    weights: Weights,
    cfg: AllocatorConfig,
    acc: AccuracyFn,
    extra: ExtraStart,
    base: AllocatorResult,
) -> AllocatorResult:
    """Fold one extra multi-start candidate into an already-solved result.

    Runs the full Alg. A2 pipeline (P3/P5/PGD inner solvers, repair,
    hardening) from ``extra``'s (f, P, X) — under every inner the config
    races, like the built-in starts — then picks the better of {base
    result, extra candidate(s)} by the same objective scoring the multi-start
    selection uses.

    Guarantees (the warm-start equivalence rows, tests/test_warmstart.py):

    * **Dominance**: the selected objective is ``min(base, candidates)``, so
      a warm start can only help or tie — never hurt — no matter how stale
      or wrong-scenario the cached entry is (a garbage candidate scores +inf
      via the finiteness guard and loses).
    * **Cold equivalence**: with ``extra.valid == 0`` the candidates are
      masked to +inf and ``argmin`` (first-occurrence tie-break) returns the
      ``base`` leaves unchanged — bit-for-bit, because selection is a gather
      over stacked results, and ``base`` itself was produced by the
      unmodified cold program.

    ``extra`` may carry a leading candidate axis (``valid`` of shape (C,)):
    each candidate is refined under every inner and all compete in one
    argmin, per-candidate validity masking each one independently. C == 1
    and the axis-less form trace the same candidate order, so the single-hit
    program is the legacy one.
    """
    multi = jnp.ndim(extra.valid) > 0
    n_cand = int(extra.valid.shape[0]) if multi else 1
    extras = (
        [jax.tree.map(lambda x: x[c], extra) for c in range(n_cand)]
        if multi
        else [extra]
    )
    inners = ("sca", "pgd") if cfg.inner == "auto" else (cfg.inner,)
    cands, valids = [], []
    for inner in inners:
        for e in extras:
            start = sanitize_start(params, e)
            cands.append(
                _solve_from(params, weights, cfg._replace(inner=inner), acc, start)
            )
            valids.append(e.valid)
    results = [base] + cands
    if cfg.use_kernel_objective:
        stacked_allocs = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[r.alloc for r in results]
        )
        objs = candidate_objectives(params, weights, stacked_allocs, acc)
    else:
        objs = jnp.stack([objective(params, weights, r.alloc, acc) for r in results])
    # candidates (every index > 0) only compete when their start was real AND
    # their objective is finite; the base result is never masked
    is_cand = jnp.arange(len(results)) > 0
    valid_vec = jnp.concatenate(
        [jnp.ones((1,), jnp.float32), jnp.stack(valids).astype(jnp.float32)]
    )
    ok = (valid_vec > 0.0) & jnp.isfinite(objs)
    objs = jnp.where(is_cand & ~ok, jnp.inf, objs)
    best = jnp.argmin(objs)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *results)
    return jax.tree.map(lambda x: x[best], stacked)


def _solve_batch_impl(
    params_batch, weights, acc, cfg, weights_batched, acc_batched=False
):
    w_axis = 0 if weights_batched else None
    a_axis = 0 if acc_batched else None
    return jax.vmap(
        lambda p, w, a: solve(p, w, cfg, a), in_axes=(0, w_axis, a_axis)
    )(params_batch, weights, acc)


_solve_batch_jit = jax.jit(
    _solve_batch_impl, static_argnames=("cfg", "weights_batched", "acc_batched")
)


def _refine_batch_impl(
    params_batch, weights, acc, extra, base, cfg, weights_batched, acc_batched=False
):
    """Per-scenario `refine_with_start` vmapped over the batch axis.

    ``base`` is the cold `solve_batch` result for the same batch; scenarios
    whose ``extra.valid`` is 0 pass their base row through bit-for-bit (the
    selection gathers the base leaves), so a mixed hit/miss batch never
    perturbs the misses.
    """
    w_axis = 0 if weights_batched else None
    a_axis = 0 if acc_batched else None
    return jax.vmap(
        lambda p, w, a, e, b: refine_with_start(p, w, cfg, a, e, b),
        in_axes=(0, w_axis, a_axis, 0, 0),
    )(params_batch, weights, acc, extra, base)


_refine_batch_jit = jax.jit(
    _refine_batch_impl, static_argnames=("cfg", "weights_batched", "acc_batched")
)


@functools.lru_cache(maxsize=None)
def sharded_refine_solver(mesh, weights_batched: bool, acc_batched: bool = False):
    """Jitted `_refine_batch_impl` with the scenario axis sharded on ``mesh``
    (the warm-start sibling of `sharded_batch_solver`: extra starts and the
    base result shard with the scenarios; the accuracy fit shards with them
    when ``acc_batched``, else replicates)."""
    from .distribute import replicated, scenario_sharding

    scen = scenario_sharding(mesh)
    rep = replicated(mesh)
    return jax.jit(
        _refine_batch_impl,
        static_argnames=("cfg", "weights_batched", "acc_batched"),
        in_shardings=(
            scen,
            scen if weights_batched else rep,
            scen if acc_batched else rep,
            scen,
            scen,
        ),
        out_shardings=scen,
    )


@functools.lru_cache(maxsize=None)
def sharded_batch_solver(mesh, weights_batched: bool, acc_batched: bool = False):
    """Jitted `solve_batch` body with the scenario axis sharded on ``mesh``.

    Explicit in/out shardings split every leading batch axis over the 1-D
    scenario mesh (`core.distribute`); the per-scenario solves are independent,
    so XLA partitions the program with no cross-device communication and each
    device solves B/mesh.size scenarios. Cached per
    (mesh, weights_batched, acc_batched) — `AllocatorConfig` stays a static
    jit arg, so one cache entry covers every config. The jit object is also
    the serving layer's AOT entry point (``.lower(...).compile()``).
    """
    from .distribute import replicated, scenario_sharding

    scen = scenario_sharding(mesh)
    rep = replicated(mesh)
    return jax.jit(
        _solve_batch_impl,
        static_argnames=("cfg", "weights_batched", "acc_batched"),
        in_shardings=(
            scen,
            scen if weights_batched else rep,
            scen if acc_batched else rep,
        ),
        out_shardings=scen,
    )


def solve_batch(
    params_batch: SystemParams,
    weights: Weights,
    cfg: AllocatorConfig = AllocatorConfig(),
    accuracy: AccuracyFn | None = None,
    *,
    weights_batched: bool = False,
    acc_batched: bool = False,
    mesh=None,
    extra_starts: ExtraStart | None = None,
) -> AllocatorResult:
    """Batched Alg. A2: solve B scenarios in one jitted, vmapped call.

    ``params_batch`` is a batch-stacked ``SystemParams`` (`stack_params` /
    `sample_params_batch`), ``g`` of shape (B, N, K). The full pipeline —
    multi-start, the P3/P5/PGD inner solvers, rate-floor repair, objective
    scoring (the batched `kernels/fedsem_objective` path when
    ``cfg.use_kernel_objective``, see `core.scoring`) and
    `harden_x` — is vmapped, so the whole sweep is a single XLA program:
    tracing happens once per (shape, cfg), not once per scenario, and the
    per-scenario math batches into wide kernels. Returns an `AllocatorResult`
    whose leaves carry a leading B axis (use `repro.core.tree_index` to pick
    one scenario out).

    ``weights`` is broadcast to every scenario unless ``weights_batched`` is
    set, in which case its leaves must carry a matching leading B axis (used
    for weight sweeps, paper Fig. 3).

    ``accuracy`` likewise broadcasts one A(rho) fit to every scenario unless
    ``acc_batched`` is set, in which case its leaves must carry a matching
    leading B axis (`stack_accuracy`) — one power-law fit per scenario, the
    multi-tenant serving path. Rows are independent under vmap, so a uniform
    stack matches the broadcast program and mixed stacks match per-row
    as-if-alone solves, exactly (tests/test_multitenant_accuracy.py).

    ``mesh`` optionally shards the scenario axis across devices (a 1-D
    `core.distribute.scenario_mesh`): the same vmapped program compiles once
    with the batch split device_count ways and no cross-device communication.
    Batches not divisible by ``mesh.size`` are padded by replicating the tail
    scenario and sliced back — exact, since scenarios are independent.

    ``extra_starts`` optionally injects warm-start candidate(s) per scenario
    (an `ExtraStart` with leading-B leaves — optionally a (B, C) candidate
    axis for top-k hits — e.g. `repro.serve.warmstart` cache lookups): the
    cold batch solves first through the UNCHANGED program,
    then a second jitted pass (`_refine_batch_impl`) runs Alg. A2 from each
    valid start and keeps the per-scenario better of the two. ``None`` (the
    default) is exactly the cold program — bit-for-bit, which is the
    cold==disabled row of the equivalence table.
    """
    if params_batch.g.ndim != 3:
        raise ValueError(
            "solve_batch expects batch-stacked params with g of shape "
            f"(B, N, K); got g.shape={tuple(params_batch.g.shape)}. "
            "Stack scenarios with stack_params() or sample them with "
            "sample_params_batch()."
        )
    if weights_batched:
        b = params_batch.g.shape[0]
        for path, leaf in jax.tree_util.tree_leaves_with_path(weights):
            shape = jnp.shape(leaf)
            if len(shape) < 1 or shape[0] != b:
                raise ValueError(
                    "solve_batch(weights_batched=True) requires every weights "
                    f"leaf to carry a leading batch axis of size B={b} matching "
                    f"params_batch; leaf 'weights{jax.tree_util.keystr(path)}' "
                    f"has shape {shape}. Stack per-scenario weights with "
                    "stack_weights(weights_list), or drop weights_batched to "
                    "broadcast one Weights to all scenarios."
                )
    if acc_batched:
        b = params_batch.g.shape[0]
        for path, leaf in jax.tree_util.tree_leaves_with_path(
            accuracy if accuracy is not None else default_accuracy()
        ):
            shape = jnp.shape(leaf)
            if len(shape) < 1 or shape[0] != b:
                raise ValueError(
                    "solve_batch(acc_batched=True) requires every accuracy "
                    f"leaf to carry a leading batch axis of size B={b} matching "
                    f"params_batch; leaf 'accuracy{jax.tree_util.keystr(path)}' "
                    f"has shape {shape}. Stack per-scenario fits with "
                    "stack_accuracy(acc_list), or drop acc_batched to "
                    "broadcast one AccuracyFn to all scenarios."
                )
    if extra_starts is not None:
        b = params_batch.g.shape[0]
        v = jnp.shape(extra_starts.valid)
        if len(v) not in (1, 2) or v[0] != b:
            raise ValueError(
                "solve_batch(extra_starts=...) requires extra_starts.valid of "
                f"shape (B,) or (B, C) with B={b} matching params_batch; got "
                f"{v}. Stack per-scenario warm starts with a leading batch "
                "axis (repro.serve.warmstart builds these from cache hits)."
            )
    acc = accuracy or default_accuracy()
    if mesh is None:
        base = _solve_batch_jit(
            params_batch, weights, acc, cfg, weights_batched, acc_batched
        )
        if extra_starts is None:
            return base
        return _refine_batch_jit(
            params_batch,
            weights,
            acc,
            extra_starts,
            base,
            cfg,
            weights_batched,
            acc_batched,
        )

    from .distribute import pad_batch, round_up, slice_batch

    b = params_batch.g.shape[0]
    b_pad = round_up(b, mesh.size)
    if b_pad != b:
        params_batch = pad_batch(params_batch, b_pad)
        if weights_batched:
            weights = pad_batch(weights, b_pad)
        if acc_batched:
            acc = pad_batch(acc, b_pad)
        if extra_starts is not None:
            extra_starts = pad_batch(extra_starts, b_pad)
    res = sharded_batch_solver(mesh, weights_batched, acc_batched)(
        params_batch, weights, acc, cfg, weights_batched, acc_batched
    )
    if extra_starts is not None:
        res = sharded_refine_solver(mesh, weights_batched, acc_batched)(
            params_batch, weights, acc, extra_starts, res, cfg, weights_batched,
            acc_batched,
        )
    return slice_batch(res, b) if b_pad != b else res


def _solve_from(
    params: SystemParams,
    weights: Weights,
    cfg: AllocatorConfig,
    acc: AccuracyFn,
    start,
) -> AllocatorResult:
    """One Alg. A2 run from a given (f, P, X) start."""
    f, P, X = start

    def outer(carry, _):
        f, P, X = carry
        p3 = solve_p3(params, weights, P, X, acc)           # Step 1 (Theorem 1)
        payload = params.D + p3.rho * params.C
        rmin = r_min(params, p3.rho, p3.T, p3.f)
        if cfg.inner == "sca":                               # Step 2 (Alg. A1)
            sol = solve_p5(params, weights, p3.rho, p3.T, p3.f, P, X, cfg.p5)
            P_new, X_new = sol.P, sol.X
        else:
            P_new, X_new = solve_p4_pgd(
                params, weights.kappa1, payload, rmin, P, X, cfg.pgd
            )
        P_new = repair_rate_floor(params, P_new, X_new, rmin)
        cand = Allocation(p3.f, P_new, X_new, p3.rho)
        s = (
            scenario_objective(params, weights, cand, acc)
            if cfg.use_kernel_objective
            else objective(params, weights, cand, acc)
        )
        return (p3.f, P_new, X_new), s

    (f, P, X), trace = jax.lax.scan(outer, (f, P, X), None, length=cfg.outer_iters)

    # ---- hardening: binary X, re-solved powers, re-derived (f, rho) ----
    Xb = harden_x(X, params.N, params.K, params.dev_mask, params.sc_mask)
    p3 = solve_p3(params, weights, P * Xb, Xb, acc)
    payload = params.D + p3.rho * params.C
    rmin = r_min(params, p3.rho, p3.T, p3.f)
    P = power_given_x(params, weights.kappa1, payload, rmin, Xb, P0=P * Xb)
    P = repair_rate_floor(params, P, Xb, rmin)
    p3 = solve_p3(params, weights, P, Xb, acc)               # final (f, rho, T)
    alloc = Allocation(f=p3.f, P=P, X=Xb, rho=p3.rho)
    return AllocatorResult(alloc=alloc, trace=trace)
