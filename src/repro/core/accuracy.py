"""Accuracy-vs-compression-rate models A(rho) (paper Assumption 1, Fig. 8b).

The paper fits mAP-vs-rho of YOLOv5 on COCO to ``A(rho) = 0.6356 * rho**0.4025``
and only uses (i) monotonic increase, (ii) concavity, (iii) A'(rho) of the fit.
We ship that exact fit as the default, plus a generic power-law / log family and
a least-squares fitter so the FL-trained autoencoder example can regenerate the
curve from its own measurements (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["a", "b"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class AccuracyFn:
    """A(rho) = a * rho**b with a > 0, 0 < b < 1 (increasing + concave)."""

    a: jax.Array
    b: jax.Array

    def value(self, rho):
        rho = jnp.maximum(jnp.asarray(rho, jnp.float32), 1e-9)
        return self.a * jnp.power(rho, self.b)

    def deriv(self, rho):
        rho = jnp.maximum(jnp.asarray(rho, jnp.float32), 1e-9)
        return self.a * self.b * jnp.power(rho, self.b - 1.0)


def default_accuracy() -> AccuracyFn:
    """The paper's YOLOv5/COCO fit: A(rho) = 0.6356 rho^0.4025."""
    return AccuracyFn(jnp.float32(0.6356), jnp.float32(0.4025))


def stack_accuracy(acc_list) -> AccuracyFn:
    """Stack per-scenario `AccuracyFn` fits over a new leading batch axis.

    The result feeds ``solve_batch(..., acc_batched=True)`` (sibling of
    `stack_weights` for the accuracy pytree): leaves become ``a``/``b`` of
    shape (B,), one power-law fit per stacked scenario. This is how the
    serving layer rides each co-batched request's OWN A(rho) belief through
    one compiled executable — multi-tenant batches mix fits per row, and a
    uniform batch (every row the same fit) solves identically to the
    replicated-scalar program (the multi-tenant equivalence rows,
    tests/test_multitenant_accuracy.py).
    """
    acc_list = list(acc_list)
    if not acc_list:
        raise ValueError("stack_accuracy needs at least one AccuracyFn")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *acc_list)


def yolov3_accuracy() -> AccuracyFn:
    """Slightly lower-ceiling curve used for the paper's YOLOv3 line (Fig 8b).

    The paper does not print the YOLOv3 coefficients; we use a curve with the
    same concavity class for the benchmark's second line.
    """
    return AccuracyFn(jnp.float32(0.55), jnp.float32(0.45))


def fit_power_law(rhos: jnp.ndarray, accs: jnp.ndarray) -> AccuracyFn:
    """Least-squares fit of log A = log a + b log rho (as the paper's MATLAB fit)."""
    rhos = jnp.asarray(rhos, jnp.float32)
    accs = jnp.asarray(accs, jnp.float32)
    x = jnp.log(jnp.maximum(rhos, 1e-9))
    y = jnp.log(jnp.maximum(accs, 1e-9))
    xm, ym = jnp.mean(x), jnp.mean(y)
    b = jnp.sum((x - xm) * (y - ym)) / jnp.maximum(jnp.sum(jnp.square(x - xm)), 1e-12)
    log_a = ym - b * xm
    b = jnp.clip(b, 0.05, 0.95)  # keep Assumption 1 (increasing, concave)
    return AccuracyFn(jnp.exp(log_a), b)
