"""Subproblem P5(P, X, sigma): SCA + quadratic transform + KKT primal-dual.

Paper-faithful path (Alg. A1 / Theorem 2). Per outer iteration we

  1. update the quadratic-transform auxiliary  y_n = 1 / (2 (sum_k p) sigma_n)
     (eq. 37, [43]) and the SCA linearisation point x_bar = X^(i-1);
  2. seek a KKT point of the inner (fixed-y, fixed-x_bar) problem by running
     projected primal-dual gradient flow on the paper's exact partial
     Lagrangian L2 (eq. 39): primal descent on (P, X, sigma) with box
     projections, dual ascent on (beta_k, iota_nk, lambda_n, nu_n >= 0).
     The paper's Steps 1-4 solve the same KKT system by nested scalar
     bisections on the *interior* stationarity expressions (49)/(50)/(52);
     those expressions are ill-posed at box-boundary solutions (which the
     binary penalty actively drives X to), so we use the gradient flow — the
     fixed points coincide with Theorem 2's KKT points (asserted in tests via
     KKT residual checks). See DESIGN.md §4/§8.
  3. track h^(i) = kappa1 sum sigma - varsigma J(X) and stop on I_max
     (Alg. A1 lines 10-11; the trace is returned for convergence analysis).

Numerics: everything is nondimensionalised — rates in units of Bbar (so
r' = sum_k x log2(1+SNR)), payload' = (D + rho C)/Bbar [s] — which puts all
multipliers within ~2 orders of magnitude of each other instead of 8.

Note: eqs. (50)/(52) in the paper drop the `rho C_n` payload term that their
own objective (31) carries; we keep `D_n + rho C_n` consistently.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .system import device_rate
from .types import SystemParams, Weights

_EPS = 1e-12


class P5Config(NamedTuple):
    outer_iters: int = 8           # I_max of Alg. A1
    inner_iters: int = 250         # primal-dual steps per outer iteration
    lr_primal: float = 0.05       # Adam on (P, X, sigma) (normalised vars)
    lr_dual: float = 0.15          # projected ascent on multipliers
    varsigma: float = 0.5          # binary penalty factor (vs kappa1*sigma ~ J)
    nu_min: float = 1e-5           # paper: nu_n > 0 strictly


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["P", "X", "sigma", "h"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class P5Solution:
    P: jax.Array
    X: jax.Array
    sigma: jax.Array
    h: jax.Array  # objective trace (outer_iters,)


def r_min(params: SystemParams, rho, T, f) -> jnp.ndarray:
    """Combined rate floor: r_n >= max(rho C / Tsc_max, D / (T - t_c))  (§IV-B)."""
    t_c = params.eta * params.c * params.d / jnp.maximum(f, _EPS)
    slack = jnp.maximum(T - t_c, 1e-6)
    return jnp.maximum(rho * params.C / params.t_sc_max, params.D / slack)


def _linear_cap(params: SystemParams, x, x_bar):
    """Linearised power cap of (35a): [x_bar^q + q x_bar^(q-1) (x - x_bar)] Pmax."""
    q = float(params.q)
    xb = jnp.clip(x_bar, 1e-3, 1.0)
    cap = (xb**q + q * xb ** (q - 1.0) * (x - xb)) * params.p_max[:, None]
    return jnp.clip(cap, 0.0, params.p_max[:, None])


def penalty_J(x, x_bar):
    """J(X) of eq. (34) (linear in x; -varsigma*J pushes x to {0,1})."""
    return jnp.sum((2.0 * x_bar - 1.0) * (x - x_bar) + x_bar * (x_bar - 1.0))


def _adam(g, m, v, t, lr):
    m = 0.9 * m + 0.1 * g
    v = 0.999 * v + 0.001 * jnp.square(g)
    mh = m / (1 - 0.9**t)
    vh = v / (1 - 0.999**t)
    return -lr * mh / (jnp.sqrt(vh) + 1e-8), m, v


def _inner_primal_dual(params, weights, payload_nd, rmin_nd, y, x_bar, init, cfg):
    """Projected primal-dual gradient flow on L2 (eq. 39), nondimensional."""
    P0, X0, sigma0 = init
    g_nd = params.g / params.noise_sc          # SNR per watt, (N, K)
    pmax = params.p_max[:, None]
    # padded devices/subcarriers (see `pad_params`) are pinned to zero after
    # every primal step; for all-real scenarios this multiplies by ones
    m2 = params.dev_mask[:, None] * params.sc_mask[None, :]
    n_real = jnp.maximum(jnp.sum(params.dev_mask), 1.0)

    def dev_mean(x):
        # mean over *real* devices: padded entries must not skew the Adam
        # learning-rate scales below (padded p_max/sigma are placeholders)
        return jnp.sum(x * params.dev_mask) / n_real

    _LN2 = 0.6931471805599453

    def rate_nd(P, X):
        return jnp.sum(X * jnp.log1p(P * g_nd), axis=-1) / _LN2   # r / Bbar

    def lagrangian(P, X, sigma, duals):
        beta, iota, lam, nu = duals
        r = rate_nd(P, X)
        p_sum = jnp.sum(P, axis=-1)
        quad = jnp.square(p_sum) * y + 1.0 / (4.0 * y * jnp.square(jnp.maximum(sigma, _EPS)))
        return (
            weights.kappa1 * jnp.sum(sigma)
            - cfg.varsigma * penalty_J(X, x_bar)
            + jnp.sum(beta * (jnp.sum(X, axis=0) - 1.0))
            + jnp.sum(lam * (rmin_nd - r))
            + jnp.sum(iota * (P - _linear_cap(params, X, x_bar)) / pmax)
            + jnp.sum(nu * (quad * payload_nd - r))
        )

    grad_primal = jax.grad(lagrangian, argnums=(0, 1, 2))

    def residuals(P, X, sigma):
        r = rate_nd(P, X)
        p_sum = jnp.sum(P, axis=-1)
        quad = jnp.square(p_sum) * y + 1.0 / (4.0 * y * jnp.square(jnp.maximum(sigma, _EPS)))
        res_beta = jnp.sum(X, axis=0) - 1.0
        res_iota = (P - _linear_cap(params, X, x_bar)) / pmax
        res_lam = (rmin_nd - r) / jnp.maximum(rmin_nd, 1.0)
        res_nu = (quad * payload_nd - r) / jnp.maximum(rmin_nd, 1.0)
        return res_beta, res_iota, res_lam, res_nu

    def step(state, i):
        P, X, sigma, duals, moms = state
        t = i + 1.0
        gP, gX, gS = grad_primal(P, X, sigma, duals)
        gP, gX, gS = (jnp.nan_to_num(g, posinf=1e6, neginf=-1e6) for g in (gP, gX, gS))
        # normalise primal gradients to their variable scales
        (mP, vP), (mX, vX), (mS, vS) = moms
        dP, mP, vP = _adam(gP, mP, vP, t, cfg.lr_primal * dev_mean(params.p_max))
        dX, mX, vX = _adam(gX, mX, vX, t, cfg.lr_primal)
        dS, mS, vS = _adam(gS, mS, vS, t, cfg.lr_primal * jnp.maximum(dev_mean(sigma), 0.01))
        P = jnp.clip(P + dP, 0.0, pmax) * m2
        X = jnp.clip(X + dX, 0.0, 1.0) * m2
        sigma = jnp.maximum(sigma + dS, 1e-4)

        beta, iota, lam, nu = duals
        rb, ri, rl, rn = residuals(P, X, sigma)
        lr_d = cfg.lr_dual / jnp.sqrt(t)
        beta = jnp.maximum(beta + lr_d * rb, 0.0)
        iota = jnp.maximum(iota + lr_d * ri, 0.0)
        lam = jnp.maximum(lam + lr_d * rl, 0.0)
        nu = jnp.maximum(nu + lr_d * rn, cfg.nu_min)
        moms = ((mP, vP), (mX, vX), (mS, vS))
        return (P, X, sigma, (beta, iota, lam, nu), moms), None

    duals0 = (
        jnp.zeros((params.K,)),
        jnp.zeros((params.N, params.K)),
        jnp.full((params.N,), 0.1),
        # nu scaled from interior stationarity (42): nu = 2 y k1 sigma^3/payload
        # (payload floored: padded devices carry payload 0 and their nu is inert)
        jnp.maximum(
            2.0 * y * weights.kappa1 * sigma0**3 / jnp.maximum(payload_nd, 1e-30),
            cfg.nu_min,
        ),
    )
    zeros = lambda x: (jnp.zeros_like(x), jnp.zeros_like(x))
    moms0 = (zeros(P0), zeros(X0), zeros(sigma0))
    state = (P0, X0, sigma0, duals0, moms0)
    state, _ = jax.lax.scan(
        step, state, jnp.arange(cfg.inner_iters, dtype=jnp.float32)
    )
    return state[0], state[1], state[2]


def solve_p5(
    params: SystemParams,
    weights: Weights,
    rho,
    T,
    f,
    P0: jnp.ndarray,
    X0: jnp.ndarray,
    cfg: P5Config = P5Config(),
) -> P5Solution:
    """Alg. A1: SCA outer loop with quadratic-transform y-updates."""
    payload_nd = (params.D + rho * params.C) / params.bbar      # [s]
    rmin_nd = r_min(params, rho, T, f) / params.bbar

    def ratio_sigma(P, X):
        r_nd = device_rate(params, P, X) / params.bbar
        return jnp.clip(
            jnp.sum(P, -1) * payload_nd / jnp.maximum(r_nd, 1e-3), 1e-5, 1e6
        )

    sigma0 = ratio_sigma(P0, X0)                            # Alg. A1 line 3

    def outer(carry, _):
        P, X, sigma = carry
        p_sum = jnp.maximum(jnp.sum(P, -1), 1e-7)
        y = 1.0 / (2.0 * p_sum * sigma)                     # line 6 / eq. (37)
        y = jnp.clip(y, 1e-4, 1e8)
        x_bar = X                                           # SCA point
        P, X, _sig = _inner_primal_dual(
            params, weights, payload_nd, rmin_nd, y, x_bar, (P, X, sigma), cfg
        )
        sigma = ratio_sigma(P, X)                           # tight epigraph
        h = weights.kappa1 * jnp.sum(sigma) - cfg.varsigma * penalty_J(X, x_bar)
        return (P, X, sigma), h

    (P, X, sigma), hs = jax.lax.scan(
        outer, (P0, X0, sigma0), None, length=cfg.outer_iters
    )
    return P5Solution(P=P, X=X, sigma=sigma, h=hs)
