"""The paper's four baselines (Section V-B).

* Equal Allocation          — round-robin subcarriers, equal power, f = 1 GHz,
                              rho = 1.
* Communication Opt. Only   — optimise (P, X) only; f random in [0.5, 1.5] GHz,
                              rho = 1.
* Computation Opt. Only     — optimise f only (Theorem-1 machinery); P at Pmax
                              spread over an equal X; rho = 1.
* Random Allocation         — uniformly random feasible (X, P, f); rho = 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .p3 import solve_T
from .pgd import PGDConfig, solve_p4_pgd
from .types import Allocation, SystemParams, Weights


def _equal_x(params: SystemParams) -> jnp.ndarray:
    k_idx = jnp.arange(params.K)
    owner = k_idx % params.N
    return jnp.zeros((params.N, params.K)).at[owner, k_idx].set(1.0)


def _spread_power(params: SystemParams, X: jnp.ndarray, frac: float = 1.0) -> jnp.ndarray:
    n_sc = jnp.sum(X, axis=-1, keepdims=True)
    return X * frac * params.p_max[:, None] / jnp.maximum(n_sc, 1.0)


def equal_allocation(params: SystemParams) -> Allocation:
    X = _equal_x(params)
    return Allocation(
        f=jnp.full((params.N,), 1e9),
        P=_spread_power(params, X),
        X=X,
        rho=jnp.float32(1.0),
    )


def comm_opt_only(
    params: SystemParams, weights: Weights, key: jax.Array,
    cfg: PGDConfig = PGDConfig(),
) -> Allocation:
    f = jax.random.uniform(key, (params.N,), minval=0.5e9, maxval=1.5e9)
    rho = jnp.float32(1.0)
    payload = params.D + rho * params.C
    rmin = rho * params.C / params.t_sc_max          # only the SemCom deadline
    X0 = _equal_x(params)
    P0 = _spread_power(params, X0)
    P, X = solve_p4_pgd(params, weights.kappa1, payload, rmin, P0, X0, cfg)
    return Allocation(f=f, P=P, X=X, rho=rho)


def comp_opt_only(params: SystemParams, weights: Weights) -> Allocation:
    X = _equal_x(params)
    P = _spread_power(params, X)                      # P at Pmax (spread)
    from .system import device_rate, fl_tx_time

    tau = fl_tx_time(params, device_rate(params, P, X))
    T = solve_T(params, weights, tau)
    eta_cd = params.eta * params.c * params.d
    f = jnp.minimum(eta_cd / jnp.maximum(T - tau, 1e-9), params.f_max)
    return Allocation(f=f, P=P, X=X, rho=jnp.float32(1.0))


def random_allocation(params: SystemParams, key: jax.Array) -> Allocation:
    k_own, k_p, k_f = jax.random.split(key, 3)
    owner = jax.random.randint(k_own, (params.K,), 0, params.N)
    X = jnp.zeros((params.N, params.K)).at[owner, jnp.arange(params.K)].set(1.0)
    # random power, rescaled into the feasible region (13a)+(13b)
    raw = jax.random.uniform(k_p, (params.N, params.K)) * X
    scale = jnp.minimum(
        1.0, params.p_max / jnp.maximum(jnp.sum(raw, -1), 1e-12)
    )
    P = raw * scale[:, None]
    f = jax.random.uniform(k_f, (params.N,), minval=0.1e9) * (params.f_max / 2e9) * 2.0
    f = jnp.minimum(f, params.f_max)
    return Allocation(f=f, P=P, X=X, rho=jnp.float32(1.0))
