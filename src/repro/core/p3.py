"""Closed-form solver for subproblem P3(f, rho, T) — paper Theorem 1.

Given fixed (P, X):
  * rho* solves Delta(rho) = sum_n kappa1 p_n C_n / r_n - kappa3 sum_n A'(rho) = 0
    (eq. 20/24), clipped at rho_max = min(1, min_n Tsc_max r_n / C_n);
  * T# solves F(T) = sum_n 2 kappa1 xi (min(eta c d/(T - tau), fmax))^3 - kappa2 = 0
    (eq. 28) by bisection;
  * f*_n = min(eta c_n d_n / (T# - tau_n), fmax)   (eq. 29)
  * T*   = max_n tau_n + eta c_n d_n / f*_n        (eq. 30)

Bisections are fixed-iteration ``lax.fori_loop`` so the solver jits and vmaps.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .accuracy import AccuracyFn, default_accuracy
from .system import comp_time, device_power, device_rate, fl_tx_time
from .types import SystemParams, Weights

_RHO_LO = 1e-4


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["f", "rho", "T"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class P3Solution:
    f: jax.Array
    rho: jax.Array
    T: jax.Array


def _bisect(fn, lo, hi, iters: int = 60):
    """Root of a scalar monotone function on [lo, hi] (sign change assumed)."""
    f_lo = fn(lo)

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        same_side = jnp.sign(fn(mid)) == jnp.sign(f_lo)
        lo = jnp.where(same_side, mid, lo)
        hi = jnp.where(same_side, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def solve_rho(
    params: SystemParams,
    weights: Weights,
    r: jnp.ndarray,
    p_n: jnp.ndarray,
    accuracy: AccuracyFn,
) -> jnp.ndarray:
    """Optimal compression rate, eq. (24)."""
    # marginal SemCom energy cost of rho (constant in rho)
    cost = jnp.sum(weights.kappa1 * p_n * params.C / jnp.maximum(r, 1e-12))

    def delta(rho):
        # accuracy gain counts real devices only (padded ones have dev_mask 0)
        return cost - weights.kappa3 * jnp.sum(
            params.dev_mask * accuracy.deriv(rho)
        )

    # Delta is increasing in rho (A' decreasing). Root in [_RHO_LO, 1] if sign
    # change; else the optimum sits at the boundary with the right sign.
    rho_hash = jnp.where(
        delta(_RHO_LO) >= 0.0,
        _RHO_LO,
        jnp.where(delta(1.0) <= 0.0, 1.0, _bisect(delta, jnp.float32(_RHO_LO), jnp.float32(1.0))),
    )
    # padded devices have C = 0; max() keeps their deadline ratio finite and
    # huge so they never bind rho_max
    rho_max = jnp.minimum(
        1.0,
        jnp.min(params.t_sc_max * jnp.maximum(r, 1e-12) / jnp.maximum(params.C, 1e-30)),
    )
    return jnp.clip(jnp.minimum(rho_hash, rho_max), _RHO_LO, 1.0)


def solve_T(params: SystemParams, weights: Weights, tau: jnp.ndarray) -> jnp.ndarray:
    """Bisection on F(T) = sum 2 k1 xi f_n(T)^3 - k2 = 0 (eq. 28)."""
    eta_cd = params.eta * params.c * params.d

    def F(T):
        f = jnp.minimum(eta_cd / jnp.maximum(T - tau, 1e-9), params.f_max)
        return jnp.sum(2.0 * weights.kappa1 * params.xi * f**3) - weights.kappa2

    t_lo = jnp.max(tau + eta_cd / params.f_max)

    # grow hi until F < 0 (F -> -kappa2 < 0 as T -> inf)
    def grow(_, hi):
        return jnp.where(F(hi) > 0.0, hi * 2.0, hi)

    t_hi = jax.lax.fori_loop(0, 40, grow, t_lo * 2.0 + 1.0)
    t_star = _bisect(F, t_lo, t_hi)
    # if even the smallest feasible T has F <= 0, energy always wins: T = t_lo
    return jnp.where(F(t_lo) <= 0.0, t_lo, t_star)


def solve_p3(
    params: SystemParams,
    weights: Weights,
    P: jnp.ndarray,
    X: jnp.ndarray,
    accuracy: AccuracyFn | None = None,
) -> P3Solution:
    """Theorem 1: optimal (f, rho, T) given fixed (P, X)."""
    acc = accuracy or default_accuracy()
    r = device_rate(params, P, X)
    p_n = device_power(P)
    tau = fl_tx_time(params, r)

    rho = solve_rho(params, weights, r, p_n, acc)
    T_hash = solve_T(params, weights, tau)
    eta_cd = params.eta * params.c * params.d
    f = jnp.minimum(eta_cd / jnp.maximum(T_hash - tau, 1e-9), params.f_max)
    T = jnp.max(tau + comp_time(params, f))
    return P3Solution(f=f, rho=rho, T=T)
