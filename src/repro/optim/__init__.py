"""repro.optim"""
