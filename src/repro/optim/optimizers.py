"""Raw-JAX optimizers (no optax in this container): SGD, AdamW, schedules.

API mirrors the (init, update) convention: `state = init(params)` and
`params, state = update(grads, state, params)`. All pytree-polymorphic.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: object | None = None
    nu: object | None = None


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr


def sgd(lr: float | Callable, momentum: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads, state, params):
        step = state.step + 1
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            delta = mu
        else:
            mu, delta = None, grads
        lr_t = lr_fn(step)
        new = jax.tree.map(lambda p, d: p - lr_t * d.astype(p.dtype), params, delta)
        return new, OptState(step=step, mu=mu)

    return init, update


def adamw(
    lr: float | Callable,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        lr_t = lr_fn(step)

        def upd(p, m, v):
            mh = m / (1 - b1**t)
            vh = v / (1 - b2**t)
            d = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * d).astype(p.dtype)

        new = jax.tree.map(upd, params, mu, nu)
        return new, OptState(step=step, mu=mu, nu=nu)

    return init, update
