"""Test-support utilities (dependency fallbacks; no jax imports here)."""
from ._hypothesis_shim import install_hypothesis_fallback

__all__ = ["install_hypothesis_fallback"]
