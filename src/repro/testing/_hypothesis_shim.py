"""Minimal `hypothesis` fallback so the property tests run without the
real package installed.

The execution image bakes in the jax toolchain but no property-testing
library, and the build rules forbid installing new packages at test time.
`pyproject.toml` declares the real ``hypothesis`` under the ``test`` extra —
environments that can install it (CI does) get the real engine, and
``tests/conftest.py`` only installs this shim when the import fails.

Scope is deliberately tiny — exactly the subset the test suite uses:

  * ``hypothesis.settings(max_examples=..., deadline=...)`` as a decorator
    (applied above ``given``),
  * ``hypothesis.given(**kwargs)`` with keyword strategies,
  * ``hypothesis.strategies.integers(min_value, max_value)``,
  * ``hypothesis.strategies.floats(min_value, max_value)``,
  * ``assume`` / ``note`` / ``HealthCheck`` no-ops.

Examples are drawn from a PRNG seeded by the test's qualified name, so runs
are deterministic; there is no shrinking or example database.
"""
from __future__ import annotations

import inspect
import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=0, max_value=2**31 - 1) -> _Strategy:
    lo, hi = int(min_value), int(max_value)
    return _Strategy(lambda rng: rng.randint(lo, hi))


def floats(min_value=0.0, max_value=1.0, **_ignored) -> _Strategy:
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: rng.choice(pool))


class settings:
    """Settings object usable as a decorator, like the real one."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = int(max_examples)
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


def given(*pos_strategies, **kw_strategies):
    if pos_strategies:
        raise TypeError("hypothesis shim supports keyword strategies only")

    def deco(fn):
        sig = inspect.signature(fn)
        passthrough = [
            p for name, p in sig.parameters.items() if name not in kw_strategies
        ]

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {
                    k: s.example_from(rng) for k, s in kw_strategies.items()
                }
                fn(*args, **kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # hide the strategy params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=passthrough)
        return wrapper

    return deco


def assume(condition) -> bool:
    return bool(condition)


def note(_msg) -> None:
    pass


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


def install_hypothesis_fallback() -> None:
    """Register shim modules as `hypothesis` / `hypothesis.strategies`.

    No-op if the real package is importable or a shim is already installed —
    the real engine (with shrinking and an example database) must always win
    when present, regardless of whether the caller imported it first.
    """
    if "hypothesis" in sys.modules:
        return
    import importlib.util

    if importlib.util.find_spec("hypothesis") is not None:
        return  # real package installed but not yet imported: leave it be
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for mod_fn in (integers, floats, booleans, sampled_from):
        setattr(strat, mod_fn.__name__, mod_fn)
    hyp.settings = settings
    hyp.given = given
    hyp.assume = assume
    hyp.note = note
    hyp.HealthCheck = HealthCheck
    hyp.strategies = strat
    hyp.__version__ = "0.0.0-fedsem-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
