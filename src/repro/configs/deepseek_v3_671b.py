"""DeepSeek-V3 671B [arXiv:2412.19437].

61L, d_model 7168, 128 heads, d_ff 2048 (expert hidden), vocab 129280.
MLA: q_lora 1536, kv_lora 512, qk_nope 128, decoupled rope 64.
MoE: 1 shared + 256 routed top-8; first 3 layers dense (d_ff 18432).
(MTP head noted in DESIGN.md; main next-token head implemented.)
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense-prefix FFN width
    vocab=129280,
    block_pattern=("attn",),
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    n_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
)
