"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56 heads (GQA kv=8), dense d_ff 4864, vocab 32000;
MoE: 128 experts top-2 with a dense FFN residual in parallel (Arctic's
"dense-MoE hybrid": every layer = dense residual MLP + 128e top-2 MoE).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    rope_theta=1e6,
    block_pattern=("attn",),
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    moe_dense_residual=True,
)
