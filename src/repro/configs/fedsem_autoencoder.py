"""The paper's own SemCom autoencoder (configured via repro.semcom)."""
from repro.semcom.autoencoder import AEConfig

CONFIG = AEConfig(image_size=32, channels=3, hidden=16, base_latent=8, rho=1.0)
