"""Architecture registry: `--arch <id>` resolves here."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "arctic_480b",
    "deepseek_v3_671b",
    "rwkv6_1_6b",
    "jamba_1_5_large_398b",
    "starcoder2_3b",
    "gemma2_9b",
    "qwen2_5_3b",
    "hubert_xlarge",
    "gemma2_2b",
    "pixtral_12b",
    "fedsem_autoencoder",   # the paper's own model (not an LM config)
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    name = _ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def list_archs():
    return [a for a in ARCHS if a != "fedsem_autoencoder"]
