"""Gemma-2 2B [arXiv:2408.00118].

26L, d_model 2304, 8 heads (GQA kv=4), d_ff 9216, vocab 256000; same
local/global + softcap recipe as 9B.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    ffn_kind="geglu",
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    tie_embeddings=True,
)
