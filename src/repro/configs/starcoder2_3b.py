"""StarCoder2-3B [arXiv:2402.19173].

30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152; RoPE,
gelu MLP with biases (starcoder2 uses standard MLP).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    ffn_kind="gelu",
    qkv_bias=True,
    rope_theta=1e5,
    block_pattern=("attn",),
    # long_500k runs only as the sliding-window variant (DESIGN.md §5)
    sliding_window=4096,
)
