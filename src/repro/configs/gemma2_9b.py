"""Gemma-2 9B [arXiv:2408.00118].

42L, d_model 3584, 16 heads (GQA kv=8), d_ff 14336, vocab 256000;
local(4096)/global alternation, attn softcap 50, final softcap 30,
GeGLU, pre+post block norms, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    ffn_kind="geglu",
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    tie_embeddings=True,
)
