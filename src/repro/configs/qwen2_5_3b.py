"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B family card].

36L, d_model 2048, 16 heads (GQA kv=2), d_ff 11008, vocab 151936; QKV bias,
swiglu, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    block_pattern=("attn",),
    tie_embeddings=True,
    # long_500k runs only as the sliding-window variant (DESIGN.md §5)
    sliding_window=4096,
)
