"""RWKV6 "Finch" 1.6B [arXiv:2404.05892].

24L, d_model 2048 (attention-free), channel-mix d_ff 7168, vocab 65536.
Data-dependent decay is the RWKV6 contribution (kept); see DESIGN.md for
the token-shift simplification.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,              # d_model / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
)
