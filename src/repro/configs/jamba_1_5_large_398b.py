"""Jamba-1.5-Large 398B [arXiv:2403.19887 / Jamba-1.5 report].

72L, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536.
Mamba:attention 7:1 interleave (1 attn per 8-layer period); MoE 16e top-2 on
every other layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    moe_d_ff=24576,
    ssm_state=16,
    ssm_expand=2,
)
