"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409].

Decoder (mistral-nemo backbone): 40L, d_model 5120, 32 heads (GQA kv=8),
d_ff 14336, vocab 131072. Pixtral-ViT vision encoder + projector are a STUB:
input_specs provides patch embeddings (frontend_dim 1024) scattered over the
leading positions. long_500k runs only as the sliding-window variant.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,
    block_pattern=("attn",),
    frontend="vision",
    frontend_dim=1024,
    sliding_window=4096,
)
