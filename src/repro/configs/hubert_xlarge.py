"""HuBERT X-Large [arXiv:2106.07447].

48L encoder-only, d_model 1280, 16 heads (MHA kv=16), d_ff 5120, 504
masked-prediction classes. Conv feature extractor is a STUB: input_specs
provides precomputed frame embeddings (frontend_dim 512) -> linear proj.
No decode shapes (encoder-only; DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    n_classes=504,
    ffn_kind="gelu",
    causal=False,
    block_pattern=("attn",),
    frontend="audio",
    frontend_dim=512,
)
