"""Pallas TPU kernel: RWKV6 WKV recurrence, chunked over time.

Grid = (B, H, S/ct); the time-chunk axis is innermost/sequential, carrying the
per-head state S in VMEM scratch (hd x hd fp32) across chunks — the classic
"state stays on-chip, activations stream through" TPU layout for linear
attention. Inside a chunk the recurrence is a fori_loop over ct steps of
rank-1 updates (VPU work; hd = 64 keeps the state tile register-friendly).

    y_t = r_t (S + diag(u) k_t^T v_t)
    S  <- diag(w_t) S + k_t^T v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CT = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, ct):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0, 0].astype(jnp.float32)           # (hd,) bonus

    def step(t, S):
        r_t = r_ref[0, 0, t].astype(jnp.float32)  # (hd,)
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]          # (hd, hd)
        y = jnp.sum((S + u[:, None] * kv) * r_t[:, None], axis=0)
        o_ref[0, 0, t] = y.astype(o_ref.dtype)
        return w_t[:, None] * S + kv

    state_ref[...] = jax.lax.fori_loop(0, ct, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("ct", "interpret"))
def rwkv6_scan_pallas(r, k, v, w, u, *, ct=DEFAULT_CT, interpret=False):
    """r/k/v/w: (B, H, S, hd); u: (H, hd). Returns y: (B, H, S, hd).

    w is the per-step decay in (0, 1) (already exp(-exp(.))-transformed).
    """
    B, H, S, hd = r.shape
    assert S % ct == 0
    grid = (B, H, S // ct)
    seq_spec = pl.BlockSpec((1, 1, ct, hd), lambda b, h, ic: (b, h, ic, 0))
    u_spec = pl.BlockSpec((1, 1, hd), lambda b, h, ic: (h, 0, 0))

    return pl.pallas_call(
        functools.partial(_kernel, ct=ct),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u.reshape(H, 1, hd))
