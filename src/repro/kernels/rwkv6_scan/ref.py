"""Pure-jnp oracle for the WKV6 recurrence (sequential lax.scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, state=None):
    """r/k/v/w: (B, H, S, hd); u: (H, hd). Returns (y, final_state)."""
    B, H, S, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S_state, inp):
        r_t, k_t, v_t, w_t = (x.astype(jnp.float32) for x in inp)  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_state + uf[..., :, None] * kv)
        return w_t[..., :, None] * S_state + kv, y

    seq = tuple(x.transpose(2, 0, 1, 3) for x in (r, k, v, w))
    final, ys = jax.lax.scan(step, state, seq)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), final
