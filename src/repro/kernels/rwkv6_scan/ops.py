"""Public WKV6 op: Pallas on TPU, lax.scan oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def rwkv6_scan(r, k, v, w, u, *, use_pallas: str | bool = "auto",
               interpret: bool = False, ct: int = kernel.DEFAULT_CT):
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.rwkv6_scan_ref(r, k, v, w, u)[0]
    B, H, S, hd = r.shape
    pad = (-S) % ct
    if pad:
        r, k, v, w = (
            jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0))) for x in (r, k, v, w)
        )
        # pad decay with ones so the state is untouched by padded steps
        w = w.at[:, :, S:].set(1.0)
    out = kernel.rwkv6_scan_pallas(r, k, v, w, u, ct=ct, interpret=interpret)
    return out[:, :, :S]
