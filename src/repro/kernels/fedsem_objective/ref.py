"""Pure-jnp oracle for the FedSem objective grid evaluation.

Evaluates P1's objective (eq. 13) for G candidate allocations at once:
  f (G,N) CPU freq, p (G,N) per-device total power, r (G,N) device rate,
  rho (G,) compression rate. Infeasible candidates (SemCom deadline or f_max
  violations) evaluate to +inf.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def objective_grid(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    xi: float, eta: float,
    kappa1: float, kappa2: float, kappa3: float,
    accuracy_ab=(0.6356, 0.4025),
):
    f = jnp.asarray(f, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    r = jnp.maximum(jnp.asarray(r, jnp.float32), _EPS)
    rho = jnp.asarray(rho, jnp.float32)[:, None]
    a_acc, b_acc = accuracy_ab

    cd = (c * d)[None, :]                      # (1, N)
    tau = D[None, :] / r                       # FL upload delay
    t_c = eta * cd / jnp.maximum(f, _EPS)
    e_t = p * tau
    e_c = xi * eta * cd * jnp.square(f)
    e_sc = p * rho * C[None, :] / r
    t_fl = jnp.max(tau + t_c, axis=-1)         # (G,)
    acc = a_acc * jnp.power(jnp.maximum(rho[:, 0], 1e-9), b_acc)
    N = f.shape[-1]

    obj = (
        kappa1 * jnp.sum(e_t + e_c + e_sc, axis=-1)
        + kappa2 * t_fl
        - kappa3 * N * acc
    )
    t_sc = rho * C[None, :] / r
    bad = jnp.any(t_sc > t_sc_max[None, :], axis=-1) | jnp.any(
        f > f_max[None, :] * (1 + 1e-6), axis=-1
    )
    return jnp.where(bad, jnp.inf, obj)
