"""Pure-jnp oracle for the FedSem objective grid evaluation.

Evaluates P1's objective (eq. 13) for G candidate allocations at once:
  f (G,N) CPU freq, p (G,N) per-device total power, r (G,N) device rate,
  rho (G,) compression rate. Infeasible candidates (SemCom deadline or f_max
  violations) evaluate to +inf when ``check_feasible`` is set.

`objective_grid_batch` adds a leading scenario axis B (the serving layer's
padded-bucket batches, `solve_batch`'s multi-start scoring): f/p/r (B, G, N),
rho (B, G), per-scenario parameter vectors (B, N), and *runtime* objective
weights / accuracy coefficients — scalars or (B,) arrays — so it is traceable
with per-scenario `Weights` under jit/vmap (the per-scenario `objective_grid`
keeps its static-float weights for the exhaustive-search path).

Every formula here is written exactly as the Pallas kernel computes it
(`a * exp(b * log(rho))` rather than `rho ** b`, select-not-multiply masking),
so kernel-vs-ref parity is exact in interpret mode, not merely close.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def objective_grid_batch(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    kappa1, kappa2, kappa3,
    *,
    xi: float, eta: float,
    accuracy_ab=(0.6356, 0.4025),
    dev_mask=None,
    check_feasible: bool = True,
):
    """Objective (eq. 13) for B scenarios x G candidates -> (B, G).

    Shapes: ``f``/``p``/``r`` (B, G, N); ``rho`` (B, G); ``c``/``d``/``D``/
    ``C``/``t_sc_max``/``f_max``/``dev_mask`` (B, N). ``kappa1..3`` and the
    ``accuracy_ab`` coefficients may be python floats, scalar arrays, or (B,)
    arrays (per-scenario weights); they are runtime values, never static.

    ``dev_mask`` rows mark real devices per scenario (`pad_params` contract):
    padded rows are excluded from the device count, the energy/delay
    reductions and the feasibility checks, so a padded scenario scores
    exactly like its exact-shape twin. ``check_feasible=False`` skips the
    +inf masking and returns the raw eq. 13 value — the `system.objective`
    semantics the allocator's multi-start selection needs.
    """
    f = jnp.asarray(f, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    r = jnp.maximum(jnp.asarray(r, jnp.float32), _EPS)
    rho = jnp.asarray(rho, jnp.float32)[..., None]            # (B, G, 1)
    a_acc, b_acc = accuracy_ab
    if dev_mask is None:
        dev_mask = jnp.ones(f.shape[:1] + f.shape[-1:], jnp.float32)
    mask = jnp.asarray(dev_mask, jnp.float32)[:, None, :]      # (B, 1, N)
    real = mask > 0.0

    def col(v):  # (B,) / scalar -> (B, 1) broadcastable over candidates
        return jnp.asarray(v, jnp.float32).reshape(-1, 1)

    cd = (jnp.asarray(c, jnp.float32) * jnp.asarray(d, jnp.float32))[:, None, :]
    D2 = jnp.asarray(D, jnp.float32)[:, None, :]
    C2 = jnp.asarray(C, jnp.float32)[:, None, :]

    tau = D2 / r                                               # FL upload delay
    t_c = eta * cd / jnp.maximum(f, _EPS)
    e_t = p * tau
    e_c = xi * eta * cd * (f * f)
    e_sc = p * rho * C2 / r
    # padded rows (dev_mask 0, `pad_params`) must not leak into any device
    # reduction: select, don't multiply (masked multiply turns inf into nan)
    e_dev = jnp.where(real, e_t + e_c + e_sc, 0.0)
    t_fl = jnp.max(jnp.where(real, tau + t_c, -jnp.inf), axis=-1)       # (B, G)
    acc = jnp.asarray(a_acc, jnp.float32).reshape(-1, 1) * jnp.exp(
        jnp.asarray(b_acc, jnp.float32).reshape(-1, 1)
        * jnp.log(jnp.maximum(rho[..., 0], 1e-9))
    )
    n_dev = jnp.sum(mask[:, 0, :], axis=-1, keepdims=True)     # (B, 1) real count

    obj = (
        col(kappa1) * jnp.sum(e_dev, axis=-1)
        + col(kappa2) * t_fl
        - col(kappa3) * n_dev * acc
    )
    if not check_feasible:
        return obj
    t_sc = rho * C2 / r
    bad = jnp.any(
        (t_sc > jnp.asarray(t_sc_max, jnp.float32)[:, None, :]) & real, axis=-1
    ) | jnp.any(
        (f > jnp.asarray(f_max, jnp.float32)[:, None, :] * (1.0 + 1e-6)) & real,
        axis=-1,
    )
    return jnp.where(bad, jnp.inf, obj)


def objective_grid(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    xi: float, eta: float,
    kappa1: float, kappa2: float, kappa3: float,
    accuracy_ab=(0.6356, 0.4025),
    dev_mask=None,
    check_feasible: bool = True,
):
    """Single-scenario view of `objective_grid_batch`: f/p/r (G, N), rho (G,)."""
    if dev_mask is None:
        dev_mask = jnp.ones((jnp.shape(f)[-1],), jnp.float32)
    return objective_grid_batch(
        jnp.asarray(f)[None], jnp.asarray(p)[None], jnp.asarray(r)[None],
        jnp.asarray(rho)[None],
        jnp.asarray(c)[None], jnp.asarray(d)[None], jnp.asarray(D)[None],
        jnp.asarray(C)[None], jnp.asarray(t_sc_max)[None],
        jnp.asarray(f_max)[None],
        kappa1, kappa2, kappa3,
        xi=xi, eta=eta, accuracy_ab=accuracy_ab,
        dev_mask=jnp.asarray(dev_mask)[None],
        check_feasible=check_feasible,
    )[0]
