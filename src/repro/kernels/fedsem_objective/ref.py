"""Pure-jnp oracle for the FedSem objective grid evaluation.

Evaluates P1's objective (eq. 13) for G candidate allocations at once:
  f (G,N) CPU freq, p (G,N) per-device total power, r (G,N) device rate,
  rho (G,) compression rate. Infeasible candidates (SemCom deadline or f_max
  violations) evaluate to +inf.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def objective_grid(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    xi: float, eta: float,
    kappa1: float, kappa2: float, kappa3: float,
    accuracy_ab=(0.6356, 0.4025),
    dev_mask=None,
):
    f = jnp.asarray(f, jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    r = jnp.maximum(jnp.asarray(r, jnp.float32), _EPS)
    rho = jnp.asarray(rho, jnp.float32)[:, None]
    a_acc, b_acc = accuracy_ab
    if dev_mask is None:
        dev_mask = jnp.ones((f.shape[-1],), jnp.float32)
    real = (jnp.asarray(dev_mask, jnp.float32) > 0.0)[None, :]  # (1, N)

    cd = (c * d)[None, :]                      # (1, N)
    tau = D[None, :] / r                       # FL upload delay
    t_c = eta * cd / jnp.maximum(f, _EPS)
    e_t = p * tau
    e_c = xi * eta * cd * jnp.square(f)
    e_sc = p * rho * C[None, :] / r
    # padded rows (dev_mask 0, `pad_params`) must not leak into any device
    # reduction: select, don't multiply (masked multiply turns inf into nan)
    e_dev = jnp.where(real, e_t + e_c + e_sc, 0.0)
    t_fl = jnp.max(jnp.where(real, tau + t_c, -jnp.inf), axis=-1)   # (G,)
    acc = a_acc * jnp.power(jnp.maximum(rho[:, 0], 1e-9), b_acc)
    n_dev = jnp.sum(jnp.asarray(dev_mask, jnp.float32))             # real count

    obj = (
        kappa1 * jnp.sum(e_dev, axis=-1)
        + kappa2 * t_fl
        - kappa3 * n_dev * acc
    )
    t_sc = rho * C[None, :] / r
    bad = jnp.any((t_sc > t_sc_max[None, :]) & real, axis=-1) | jnp.any(
        (f > f_max[None, :] * (1 + 1e-6)) & real, axis=-1
    )
    return jnp.where(bad, jnp.inf, obj)
