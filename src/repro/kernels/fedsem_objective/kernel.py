"""Pallas TPU kernel: FedSem objective (eq. 13) over a grid of candidates.

The exhaustive / random-search baselines evaluate the P1 objective for ~1e8
candidate allocations; this is the paper-core's only compute hot-spot
(DESIGN.md §4). Layout is transposed to (N, G) so the candidate axis G sits on
the 128-wide lane dimension of the VPU; device axis N (4..16) rides sublanes.
Each grid step processes a (N, BG) VMEM tile; the N-reductions and max happen
on-chip, emitting a (1, BG) objective tile.

Two entry points:

* `objective_grid_pallas` — one scenario, G candidates, *static* objective
  weights (the exhaustive-search path, where weights are python floats).
* `objective_batch_pallas` — a leading scenario axis B (grid `(B, G/BG)`),
  per-scenario parameter rows and *runtime* weight / accuracy scalars, so the
  batched evaluation paths (`solve_batch` multi-start scoring, serving's
  padded-bucket batches) trace it with per-scenario `Weights` under jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12
BLOCK_G = 512  # lane-aligned candidate tile (4 x 128)
LANE = 128     # TPU lane width: smallest useful candidate tile


def _kernel(
    f_ref, p_ref, r_ref, rho_ref,       # (N, BG), (N, BG), (N, BG), (1, BG)
    c_ref, d_ref, D_ref, C_ref, tsc_ref, fmax_ref, mask_ref,  # (N, 1) each
    obj_ref,                            # out: (1, BG)
    *, xi: float, eta: float, k1: float, k2: float, k3: float,
    a_acc: float, b_acc: float,
):
    f = f_ref[...]
    p = p_ref[...]
    r = jnp.maximum(r_ref[...], _EPS)
    rho = rho_ref[...]                  # (1, BG)
    real = mask_ref[...] > 0.0          # (N, 1) validity (pad_params contract)

    cd = c_ref[...] * d_ref[...]        # (N, 1)
    tau = D_ref[...] / r
    t_c = eta * cd / jnp.maximum(f, _EPS)
    e_t = p * tau
    e_c = xi * eta * cd * (f * f)
    e_sc = p * rho * C_ref[...] / r
    # padded rows must not leak into any device-axis reduction: select, don't
    # multiply (a masked multiply turns inf garbage into nan)
    e_dev = jnp.where(real, e_t + e_c + e_sc, 0.0)
    t_fl = jnp.max(
        jnp.where(real, tau + t_c, -jnp.inf), axis=0, keepdims=True
    )                                                          # (1, BG)
    acc = a_acc * jnp.exp(b_acc * jnp.log(jnp.maximum(rho, 1e-9)))
    n_dev = jnp.sum(mask_ref[...], axis=0, keepdims=True)      # (1, 1) real count

    obj = (
        k1 * jnp.sum(e_dev, axis=0, keepdims=True)
        + k2 * t_fl
        - k3 * n_dev * acc
    )
    t_sc = rho * C_ref[...] / r
    bad = jnp.any((t_sc > tsc_ref[...]) & real, axis=0, keepdims=True) | jnp.any(
        (f > fmax_ref[...] * (1.0 + 1e-6)) & real, axis=0, keepdims=True
    )
    obj_ref[...] = jnp.where(bad, jnp.inf, obj)


@functools.partial(
    jax.jit,
    static_argnames=("xi", "eta", "k1", "k2", "k3", "a_acc", "b_acc", "interpret"),
)
def objective_grid_pallas(
    f_t, p_t, r_t, rho,                 # (N, G) x3, (G,)
    c, d, D, C, t_sc_max, f_max,        # (N,) each
    dev_mask,                           # (N,) 1 = real device, 0 = padding
    *, xi, eta, k1, k2, k3, a_acc, b_acc, interpret: bool = False,
):
    N, G = f_t.shape
    assert G % BLOCK_G == 0, "pad G to a multiple of BLOCK_G before calling"
    col = lambda v: jnp.asarray(v, jnp.float32).reshape(N, 1)
    rho2 = jnp.asarray(rho, jnp.float32).reshape(1, G)

    grid = (G // BLOCK_G,)
    cand_spec = pl.BlockSpec((N, BLOCK_G), lambda i: (0, i))
    row_spec = pl.BlockSpec((1, BLOCK_G), lambda i: (0, i))
    vec_spec = pl.BlockSpec((N, 1), lambda i: (0, 0))

    out = pl.pallas_call(
        functools.partial(
            _kernel, xi=xi, eta=eta, k1=k1, k2=k2, k3=k3, a_acc=a_acc, b_acc=b_acc
        ),
        grid=grid,
        in_specs=[cand_spec, cand_spec, cand_spec, row_spec] + [vec_spec] * 7,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((1, G), jnp.float32),
        interpret=interpret,
    )(
        f_t.astype(jnp.float32),
        p_t.astype(jnp.float32),
        r_t.astype(jnp.float32),
        rho2,
        col(c), col(d), col(D), col(C), col(t_sc_max), col(f_max),
        col(dev_mask),
    )
    return out[0]


# ---------------------------------------------------------------------------
# batched-over-scenarios kernel
# ---------------------------------------------------------------------------


def _batch_kernel(
    f_ref, p_ref, r_ref,                # (1, N, BG) candidate tiles
    rho_ref,                            # (1, BG)
    c_ref, d_ref, D_ref, C_ref, tsc_ref, fmax_ref, mask_ref,  # (1, N, 1)
    k1_ref, k2_ref, k3_ref, aa_ref, ab_ref,                   # (1, 1)
    obj_ref,                            # out: (1, BG)
    *, xi: float, eta: float, check_feasible: bool,
):
    f = f_ref[0]                        # (N, BG)
    p = p_ref[0]
    r = jnp.maximum(r_ref[0], _EPS)
    rho = rho_ref[...]                  # (1, BG)
    real = mask_ref[0] > 0.0            # (N, 1) validity (pad_params contract)
    k1 = k1_ref[0, 0]
    k2 = k2_ref[0, 0]
    k3 = k3_ref[0, 0]

    cd = c_ref[0] * d_ref[0]            # (N, 1)
    tau = D_ref[0] / r
    t_c = eta * cd / jnp.maximum(f, _EPS)
    e_t = p * tau
    e_c = xi * eta * cd * (f * f)
    e_sc = p * rho * C_ref[0] / r
    # padded rows must not leak into any device-axis reduction: select, don't
    # multiply (a masked multiply turns inf garbage into nan)
    e_dev = jnp.where(real, e_t + e_c + e_sc, 0.0)
    t_fl = jnp.max(
        jnp.where(real, tau + t_c, -jnp.inf), axis=0, keepdims=True
    )                                                          # (1, BG)
    acc = aa_ref[0, 0] * jnp.exp(
        ab_ref[0, 0] * jnp.log(jnp.maximum(rho, 1e-9))
    )
    n_dev = jnp.sum(mask_ref[0], axis=0, keepdims=True)        # (1, 1) real count

    obj = (
        k1 * jnp.sum(e_dev, axis=0, keepdims=True)
        + k2 * t_fl
        - k3 * n_dev * acc
    )
    if check_feasible:
        t_sc = rho * C_ref[0] / r
        bad = jnp.any(
            (t_sc > tsc_ref[0]) & real, axis=0, keepdims=True
        ) | jnp.any(
            (f > fmax_ref[0] * (1.0 + 1e-6)) & real, axis=0, keepdims=True
        )
        obj = jnp.where(bad, jnp.inf, obj)
    obj_ref[...] = obj


@functools.partial(
    jax.jit,
    static_argnames=("xi", "eta", "check_feasible", "interpret", "block_g"),
)
def objective_batch_pallas(
    f_t, p_t, r_t,                      # (B, N, G) each
    rho,                                # (B, G)
    c, d, D, C, t_sc_max, f_max,        # (B, N) each
    dev_mask,                           # (B, N) 1 = real device, 0 = padding
    k1, k2, k3, a_acc, b_acc,           # (B,) runtime weights / accuracy fit
    *, xi, eta,
    check_feasible: bool = True,
    interpret: bool = False,
    block_g: int = BLOCK_G,
):
    """Batched objective grid: one scenario per leading-grid step.

    The grid is (B, G // block_g): scenario b's parameter rows and weight
    scalars are re-fetched per candidate tile, candidates ride the lane
    dimension exactly as in the single-scenario kernel. Weights and the
    accuracy power-law coefficients are *runtime* (B,) inputs, so the same
    compiled kernel serves every `Weights`, including per-scenario batches.
    """
    B, N, G = f_t.shape
    assert G % block_g == 0, "pad G to a multiple of block_g before calling"
    vec = lambda v: jnp.asarray(v, jnp.float32).reshape(B, N, 1)
    scal = lambda v: jnp.broadcast_to(
        jnp.asarray(v, jnp.float32).reshape(-1, 1), (B, 1)
    )

    grid = (B, G // block_g)
    cand_spec = pl.BlockSpec((1, N, block_g), lambda b, i: (b, 0, i))
    row_spec = pl.BlockSpec((1, block_g), lambda b, i: (b, i))
    vec_spec = pl.BlockSpec((1, N, 1), lambda b, i: (b, 0, 0))
    scal_spec = pl.BlockSpec((1, 1), lambda b, i: (b, 0))

    return pl.pallas_call(
        functools.partial(
            _batch_kernel, xi=xi, eta=eta, check_feasible=check_feasible
        ),
        grid=grid,
        in_specs=(
            [cand_spec] * 3 + [row_spec] + [vec_spec] * 7 + [scal_spec] * 5
        ),
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((B, G), jnp.float32),
        interpret=interpret,
    )(
        f_t.astype(jnp.float32),
        p_t.astype(jnp.float32),
        r_t.astype(jnp.float32),
        jnp.asarray(rho, jnp.float32),
        vec(c), vec(d), vec(D), vec(C), vec(t_sc_max), vec(f_max),
        vec(dev_mask),
        scal(k1), scal(k2), scal(k3), scal(a_acc), scal(b_acc),
    )
