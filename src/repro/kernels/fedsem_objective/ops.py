"""jit'd public wrapper for the FedSem objective grid.

Dispatch: on TPU the Pallas kernel runs compiled; elsewhere we use the pure
jnp oracle (`ref.py`) — Pallas-in-interpret-mode is for correctness tests,
not for the 1e8-candidate exhaustive sweeps on one CPU core.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _pad_to(x, g_pad, axis=0, fill=0.0):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, g_pad - x.shape[axis])
    return jnp.pad(x, pad, constant_values=fill)


def objective_grid(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    xi: float, eta: float,
    kappa1: float, kappa2: float, kappa3: float,
    accuracy_ab=(0.6356, 0.4025),
    *,
    dev_mask=None,
    use_pallas: str | bool = "auto",
    interpret: bool = False,
):
    """Objective (eq. 13) for G candidates. f/p/r: (G, N); rho: (G,).

    ``dev_mask`` (N,) marks real devices (`pad_params` contract): padded rows
    are excluded from the device count, the energy/delay reductions and the
    feasibility checks, so the grid score of a padded scenario matches
    `system.objective` on the exact-shape one. None = every device real.
    """
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.objective_grid(
            f, p, r, rho, c, d, D, C, t_sc_max, f_max,
            xi, eta, kappa1, kappa2, kappa3, accuracy_ab, dev_mask,
        )

    G = f.shape[0]
    if dev_mask is None:
        dev_mask = jnp.ones((jnp.shape(f)[-1],), jnp.float32)
    g_pad = -(-G // kernel.BLOCK_G) * kernel.BLOCK_G
    f_t = _pad_to(jnp.asarray(f, jnp.float32), g_pad).T
    p_t = _pad_to(jnp.asarray(p, jnp.float32), g_pad).T
    r_t = _pad_to(jnp.asarray(r, jnp.float32), g_pad, fill=1.0).T
    rho_p = _pad_to(jnp.asarray(rho, jnp.float32), g_pad, fill=1.0)
    a_acc, b_acc = accuracy_ab
    out = kernel.objective_grid_pallas(
        f_t, p_t, r_t, rho_p, c, d, D, C, t_sc_max, f_max, dev_mask,
        xi=float(xi), eta=float(eta),
        k1=float(kappa1), k2=float(kappa2), k3=float(kappa3),
        a_acc=float(a_acc), b_acc=float(b_acc),
        interpret=interpret,
    )
    return out[:G]
