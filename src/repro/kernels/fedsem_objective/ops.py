"""jit'd public wrappers for the FedSem objective grid.

Dispatch: on TPU the Pallas kernels run compiled; elsewhere we use the pure
jnp oracle (`ref.py`) — Pallas-in-interpret-mode is for correctness tests,
not for the 1e8-candidate exhaustive sweeps on one CPU core.

* `objective_grid` — one scenario, static float weights (exhaustive search).
* `objective_grid_batch` — leading scenario axis B with runtime (traceable)
  weights and accuracy coefficients. This is the entry the batched evaluation
  paths use (`core.scoring` -> `solve_batch` multi-start selection, the
  serving layer's padded-bucket flush scoring, the chunked exhaustive sweep).
  It is vmap-compatible: mapping over a leading axis batches the Pallas call
  into an extra grid dimension, so `solve_batch`'s vmapped per-scenario
  scoring (a B=1 call per scenario) still compiles to one batched kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _pad_to(x, g_pad, axis=0, fill=0.0):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, g_pad - x.shape[axis])
    return jnp.pad(x, pad, constant_values=fill)


def objective_grid(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    xi: float, eta: float,
    kappa1: float, kappa2: float, kappa3: float,
    accuracy_ab=(0.6356, 0.4025),
    *,
    dev_mask=None,
    use_pallas: str | bool = "auto",
    interpret: bool = False,
):
    """Objective (eq. 13) for G candidates. f/p/r: (G, N); rho: (G,).

    ``dev_mask`` (N,) marks real devices (`pad_params` contract): padded rows
    are excluded from the device count, the energy/delay reductions and the
    feasibility checks, so the grid score of a padded scenario matches
    `system.objective` on the exact-shape one. None = every device real.
    """
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.objective_grid(
            f, p, r, rho, c, d, D, C, t_sc_max, f_max,
            xi, eta, kappa1, kappa2, kappa3, accuracy_ab, dev_mask,
        )

    G = f.shape[0]
    if dev_mask is None:
        dev_mask = jnp.ones((jnp.shape(f)[-1],), jnp.float32)
    g_pad = -(-G // kernel.BLOCK_G) * kernel.BLOCK_G
    f_t = _pad_to(jnp.asarray(f, jnp.float32), g_pad).T
    p_t = _pad_to(jnp.asarray(p, jnp.float32), g_pad).T
    r_t = _pad_to(jnp.asarray(r, jnp.float32), g_pad, fill=1.0).T
    rho_p = _pad_to(jnp.asarray(rho, jnp.float32), g_pad, fill=1.0)
    a_acc, b_acc = accuracy_ab
    out = kernel.objective_grid_pallas(
        f_t, p_t, r_t, rho_p, c, d, D, C, t_sc_max, f_max, dev_mask,
        xi=float(xi), eta=float(eta),
        k1=float(kappa1), k2=float(kappa2), k3=float(kappa3),
        a_acc=float(a_acc), b_acc=float(b_acc),
        interpret=interpret,
    )
    return out[:G]


def objective_grid_batch(
    f, p, r, rho,
    c, d, D, C, t_sc_max, f_max,
    kappa1, kappa2, kappa3,
    *,
    xi: float, eta: float,
    accuracy_ab=(0.6356, 0.4025),
    dev_mask=None,
    check_feasible: bool = True,
    use_pallas: str | bool = "auto",
    interpret: bool = False,
):
    """Objective (eq. 13) for B scenarios x G candidates -> (B, G).

    Shapes: ``f``/``p``/``r`` (B, G, N); ``rho`` (B, G); per-scenario
    parameter vectors and ``dev_mask`` (B, N). ``kappa1..3`` and
    ``accuracy_ab`` are runtime values — python floats, scalar arrays, or
    (B,) arrays for per-scenario weights — so the call traces under jit with
    `Weights` leaves (unlike `objective_grid`, whose weights are static).

    ``check_feasible=False`` skips the infeasible -> +inf masking and returns
    the raw eq. 13 score (`system.objective` semantics, used by the
    allocator's multi-start selection). The candidate axis is padded to a
    lane-aligned tile internally; ``xi``/``eta`` stay static (they are
    `SystemParams` meta, identical across any stacked batch).
    """
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.objective_grid_batch(
            f, p, r, rho, c, d, D, C, t_sc_max, f_max,
            kappa1, kappa2, kappa3,
            xi=xi, eta=eta, accuracy_ab=accuracy_ab, dev_mask=dev_mask,
            check_feasible=check_feasible,
        )

    B, G, N = jnp.shape(f)
    if dev_mask is None:
        dev_mask = jnp.ones((B, N), jnp.float32)
    # small candidate grids (multi-start scoring: G = #starts) only need one
    # lane-width tile; big grids (exhaustive) keep the full 4x128 block
    block_g = min(kernel.BLOCK_G, -(-G // kernel.LANE) * kernel.LANE)
    g_pad = -(-G // block_g) * block_g
    f_t = jnp.swapaxes(_pad_to(jnp.asarray(f, jnp.float32), g_pad, axis=1), 1, 2)
    p_t = jnp.swapaxes(_pad_to(jnp.asarray(p, jnp.float32), g_pad, axis=1), 1, 2)
    r_t = jnp.swapaxes(
        _pad_to(jnp.asarray(r, jnp.float32), g_pad, axis=1, fill=1.0), 1, 2
    )
    rho_p = _pad_to(jnp.asarray(rho, jnp.float32), g_pad, axis=1, fill=1.0)
    a_acc, b_acc = accuracy_ab
    out = kernel.objective_batch_pallas(
        f_t, p_t, r_t, rho_p, c, d, D, C, t_sc_max, f_max, dev_mask,
        kappa1, kappa2, kappa3, a_acc, b_acc,
        xi=float(xi), eta=float(eta),
        check_feasible=check_feasible,
        interpret=interpret,
        block_g=block_g,
    )
    return out[:, :G]
