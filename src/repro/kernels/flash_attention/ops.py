"""Public flash-attention op: Pallas on TPU, chunked-jnp elsewhere.

Accepts model-layout tensors q:(B,S,H,hd), k/v:(B,S,KV,hd); handles padding to
block multiples and the layout transpose the kernel wants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel


def flash_attention(
    q, k, v, *, causal=True, window=None, cap=None,
    use_pallas: str | bool = "auto", interpret: bool = False,
    bq: int = kernel.DEFAULT_BQ, bk: int = kernel.DEFAULT_BK,
):
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        from repro.models.attention import flash_attention as jnp_flash

        S = q.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        return jnp_flash(
            q, k, v, q_positions=pos, kv_positions=pos,
            causal=causal, window=window, cap=cap,
        )

    B, S, H, hd = q.shape
    KV = k.shape[2]
    s_pad = -(-S // max(bq, bk)) * max(bq, bk)
    pad = s_pad - S

    def prep(x):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3)  # (B, heads, S, hd)

    out = kernel.flash_attention_pallas(
        prep(q), prep(k), prep(v),
        causal=causal, window=window, cap=cap, bq=bq, bk=bk,
        interpret=interpret, s_valid=S,
    )
    return out.transpose(0, 2, 1, 3)[:, :S]
