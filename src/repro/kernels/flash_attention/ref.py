"""Naive O(S^2) attention oracle (independent of the chunked jnp path)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def naive_attention(q, k, v, *, causal=True, window=None, cap=None):
    """q: (B,S,H,hd); k/v: (B,S,KV,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32)) / jnp.sqrt(hd)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(v.dtype)
