"""Pallas TPU flash attention (forward) with GQA, causal/sliding-window masks
and gemma-style logit softcap.

Grid = (B, H, S/bq, S/bk); the kv-block axis is innermost so each (b, h, iq)
accumulates over kv blocks sequentially with running max / denominator held in
VMEM scratch (the standard flash recipe re-tiled for the MXU: bq x bk score
tiles with hd-contracted matmuls, 128-aligned).

GQA rides the BlockSpec index_map: the k/v block for query head h is
h // (H // KV) — no head replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale, causal, window, cap, bq, bk, n_k, s_valid):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (bq, bk)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < s_valid                       # exclude padded kv positions
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "cap", "bq", "bk", "interpret", "s_valid"),
)
def flash_attention_pallas(
    q, k, v, *, causal=True, window=None, cap=None,
    bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=False, s_valid=None,
):
    """q: (B, H, S, hd); k/v: (B, KV, S, hd); S % bq == S % bk == 0."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    n_q, n_k = S // bq, S // bk
    grid = (B, H, n_q, n_k)
    scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, cap=cap,
        bq=bq, bk=bk, n_k=n_k, s_valid=s_valid if s_valid is not None else S,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
