"""Public selective-scan op: Pallas on TPU, lax.scan oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def mamba_scan(x, dt, Bm, Cm, A, D, *, use_pallas: str | bool = "auto",
               interpret: bool = False, ct: int = kernel.DEFAULT_CT,
               bd: int = kernel.DEFAULT_BD):
    if use_pallas == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.mamba_scan_ref(x, dt, Bm, Cm, A, D)[0]
    B, S, di = x.shape
    bd = min(bd, di)
    pad = (-S) % ct
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 => state frozen
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    out = kernel.mamba_scan_pallas(x, dt, Bm, Cm, A, D, ct=ct, bd=bd,
                                   interpret=interpret)
    return out[:, :S]
