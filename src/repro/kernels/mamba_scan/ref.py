"""Pure-jnp oracle for the selective scan (sequential lax.scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, Bm, Cm, A, D, h0=None):
    """x, dt: (B,S,di); Bm, Cm: (B,S,N); A: (di,N); D: (di,).

    Returns (y: (B,S,di), final h: (B,di,N)).
    """
    B, S, di = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = (v.astype(jnp.float32) for v in inp)
        da = jnp.exp(dt_t[..., None] * Af)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t) + D * x_t
        return h, y

    seq = (x.swapaxes(0, 1), dt.swapaxes(0, 1), Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, seq)
    return ys.swapaxes(0, 1).astype(x.dtype), h
