"""Pallas TPU kernel: Mamba selective scan, chunked over time.

Grid = (B, d_inner/bd, S/ct); time chunks innermost carrying the per-channel
state h (bd, N) in VMEM scratch. The (B, S, d_inner, N) tensor a naive
implementation would materialise (terabytes at Jamba scale) never exists: each
chunk streams (x, dt, B, C) tiles through VMEM and emits y only.

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;   y_t = C_t h_t + D x_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CT = 128
DEFAULT_BD = 512


def _kernel(x_ref, dt_ref, b_ref, c_ref, A_ref, D_ref, y_ref, h_ref, *, ct):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)            # (bd, N)
    D = D_ref[...].astype(jnp.float32)            # (bd, 1)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)     # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)   # (bd,)
        B_t = b_ref[0, t].astype(jnp.float32)     # (N,)
        C_t = c_ref[0, t].astype(jnp.float32)     # (N,)
        da = jnp.exp(dt_t[:, None] * A)           # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y = jnp.sum(h * C_t[None, :], axis=-1) + D[:, 0] * x_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, ct, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("ct", "bd", "interpret"))
def mamba_scan_pallas(x, dt, Bm, Cm, A, D, *, ct=DEFAULT_CT, bd=DEFAULT_BD,
                      interpret=False):
    """x, dt: (B, S, di); Bm, Cm: (B, S, N); A: (di, N); D: (di,).

    Returns y: (B, S, di). di % bd == 0, S % ct == 0.
    """
    B, S, di = x.shape
    N = Bm.shape[-1]
    assert S % ct == 0 and di % bd == 0
    grid = (B, di // bd, S // ct)

    chan_spec = pl.BlockSpec((1, ct, bd), lambda b, id_, ic: (b, ic, id_))
    bc_spec = pl.BlockSpec((1, ct, N), lambda b, id_, ic: (b, ic, 0))
    A_spec = pl.BlockSpec((bd, N), lambda b, id_, ic: (id_, 0))
    D_spec = pl.BlockSpec((bd, 1), lambda b, id_, ic: (id_, 0))

    return pl.pallas_call(
        functools.partial(_kernel, ct=ct),
        grid=grid,
        in_specs=[chan_spec, chan_spec, bc_spec, bc_spec, A_spec, D_spec],
        out_specs=chan_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, A, D.reshape(di, 1))
