"""Pytree checkpointing to .npz (no orbax in this container).

Leaves are flattened with '/'-joined key paths; restore rebuilds into the
structure of a reference pytree (so dataclass/NamedTuple states round-trip).
Sharded arrays are gathered on save and re-sharded by the caller on restore
(`jax.device_put(tree, shardings)`).
"""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float16"):
            arr = arr.astype(np.float32)   # npz-safe; re-cast on restore
        out[key] = arr
    return out


def save(path: str, tree) -> None:
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    with np.load(path, allow_pickle=False) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, ref in flat:
            key = "/".join(str(x) for x in p)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if arr.shape != np.shape(ref):
                raise ValueError(f"{key}: shape {arr.shape} != {np.shape(ref)}")
            import jax.numpy as jnp

            leaves.append(jnp.asarray(arr).astype(jnp.asarray(ref).dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
