"""repro.checkpoint"""
