"""repro.data"""
