"""Synthetic data pipelines (no external datasets in this container).

Images: structured scenes — coloured rectangles + smooth background + texture
noise — so compression rate genuinely trades off reconstruction quality.
Tokens: Zipf-distributed LM streams with markovian bigram structure so
cross-entropy decreases meaningfully during the example training runs.

Both are pure-JAX keyed generators: deterministic, shardable, no host state.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def image_batch(key: jax.Array, batch: int, size: int = 32, channels: int = 3):
    """(B, H, W, C) images in [-1, 1]."""
    k_bg, k_rect, k_col, k_noise = jax.random.split(key, 4)

    # smooth background: low-frequency gradient per image
    coef = jax.random.normal(k_bg, (batch, 2, channels)) * 0.4
    yy, xx = jnp.mgrid[0:size, 0:size] / size
    bg = (
        coef[:, 0, None, None, :] * yy[None, :, :, None]
        + coef[:, 1, None, None, :] * xx[None, :, :, None]
    )

    # 3 random rectangles per image
    def rects(key):
        ks = jax.random.split(key, 3)
        img = jnp.zeros((size, size, channels))
        for i in range(3):
            ka, kb = jax.random.split(ks[i])
            c0 = jax.random.randint(ka, (2,), 0, size - 8)
            wh = jax.random.randint(kb, (2,), 4, size // 2)
            col = jax.random.uniform(jax.random.fold_in(kb, 7), (channels,), minval=-1, maxval=1)
            yy2, xx2 = jnp.mgrid[0:size, 0:size]
            mask = (
                (yy2 >= c0[0]) & (yy2 < c0[0] + wh[0])
                & (xx2 >= c0[1]) & (xx2 < c0[1] + wh[1])
            )
            img = jnp.where(mask[:, :, None], col[None, None, :], img)
        return img

    fg = jax.vmap(rects)(jax.random.split(k_rect, batch))
    noise = 0.05 * jax.random.normal(k_noise, (batch, size, size, channels))
    return jnp.clip(bg + fg + noise, -1.0, 1.0)


def image_stream(key: jax.Array, batch: int, size: int = 32) -> Iterator[jnp.ndarray]:
    i = 0
    while True:
        yield image_batch(jax.random.fold_in(key, i), batch, size)
        i += 1


def make_bigram_table(key: jax.Array, vocab: int, concentration: float = 0.5):
    """Row-stochastic bigram logits with Zipf-ish marginals."""
    base = -jnp.log(jnp.arange(1, vocab + 1, dtype=jnp.float32))  # Zipf prior
    noise = jax.random.gumbel(key, (vocab, vocab)) * concentration
    return base[None, :] + noise


def token_batch(key: jax.Array, table: jnp.ndarray, batch: int, seq: int):
    """(B, S+1) int32 tokens from the bigram chain (inputs + shifted labels)."""
    vocab = table.shape[0]
    k0, kseq = jax.random.split(key)
    first = jax.random.categorical(k0, jnp.broadcast_to(table[0], (batch, vocab)))

    def step(tok, k):
        nxt = jax.random.categorical(k, table[tok])
        return nxt, nxt

    keys = jax.random.split(kseq, seq)
    _, rest = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None, :], rest], axis=0).T.astype(jnp.int32)


def token_stream(key, vocab: int, batch: int, seq: int) -> Iterator[jnp.ndarray]:
    table = make_bigram_table(jax.random.fold_in(key, 999), vocab)
    i = 0
    while True:
        yield token_batch(jax.random.fold_in(key, i), table, batch, seq)
        i += 1


def partition_clients(key: jax.Array, n_clients: int, pool: int = 1024,
                      alpha: float = 0.5) -> np.ndarray:
    """Dirichlet non-IID client shares (used by the FL driver's d_n)."""
    g = jax.random.gamma(key, jnp.full((n_clients,), alpha))
    share = g / jnp.sum(g)
    return np.asarray(jnp.maximum((share * pool).astype(jnp.int32), 16))
