"""Distributed train step builder + single-host training driver.

`build_train_step(cfg, mesh)` returns a pure (state, batch) -> (state, metrics)
function suitable for pjit: loss (remat'd scan stack, MoE shard_map when the
mesh has a model axis) -> grads -> global-norm clip -> AdamW.

Run as a script for a real (small-scale) training run on the local device:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, smoke_variant
from repro.optim.optimizers import adamw, clip_by_global_norm


class TrainState(NamedTuple):
    params: dict
    opt: object


def build_train_step(cfg: ModelConfig, mesh=None, lr: float = 3e-4,
                     clip: float = 1.0, use_kernel: bool = False):
    _, opt_update = adamw(lr, weight_decay=0.01)

    def train_step(state: TrainState, batch):
        def loss(p):
            return M.loss_fn(p, cfg, batch, mesh=mesh, use_kernel=use_kernel)

        loss_val, grads = jax.value_and_grad(loss)(state.params)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt = opt_update(grads, state.opt, state.params)
        return TrainState(params, opt), {"loss": loss_val, "grad_norm": gnorm}

    return train_step


def init_state(key, cfg: ModelConfig, lr: float = 3e-4) -> TrainState:
    params = M.init_params(key, cfg)
    opt_init, _ = adamw(lr, weight_decay=0.01)
    return TrainState(params, opt_init(params))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.data.synthetic import token_stream

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(0)
    state = init_state(key, cfg, args.lr)
    step_fn = jax.jit(build_train_step(cfg, lr=args.lr))

    stream = token_stream(key, cfg.vocab, args.batch, args.seq)
    t0 = time.time()
    for i in range(args.steps):
        toks = next(stream)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(
                f"step {i:4d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({time.time()-t0:.1f}s)"
            )
    print("done")


if __name__ == "__main__":
    main()
