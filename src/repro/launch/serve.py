"""Batched serving driver: continuous-batching decode loop over a request
queue, with per-step latency stats.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --smoke \
      --requests 8 --max-new 16

Prompt ingestion uses the decode path position-by-position (prefill-with-
cache fusion is a §Perf item; logits-only prefill is exercised by the
dry-run and benchmarks).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import smoke_variant


class ServeLoop:
    """Fixed-slot continuous batching: finished sequences are replaced by
    queued requests; every slot advances one token per step."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int, mesh=None):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.max_len = max_len
        self.cache = M.init_cache(cfg, batch_slots, max_len)
        self.slots = batch_slots
        self.step_fn = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c, mesh=mesh)
        )

    def run(self, requests: list[list[int]], max_new: int, greedy=True):
        """requests: token lists. Returns dict req_idx -> generated tokens."""
        queue = list(enumerate(requests))
        active = [None] * self.slots        # (req_idx, prompt, n_emitted, out)
        results = {}
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        pos = 0
        stats = {"steps": 0, "step_times": []}

        def refill():
            for s in range(self.slots):
                if active[s] is None and queue:
                    idx, prompt = queue.pop(0)
                    active[s] = [idx, list(prompt), 0, []]

        refill()
        while any(a is not None for a in active) and pos < self.max_len - 1:
            feed = []
            for s in range(self.slots):
                a = active[s]
                if a is None:
                    feed.append(0)
                elif a[1]:                   # still ingesting the prompt
                    feed.append(a[1].pop(0))
                else:
                    feed.append(int(tok[s, 0]))
            t0 = time.time()
            logits, self.cache = self.step_fn(
                self.params, jnp.asarray(feed, jnp.int32)[:, None],
                jnp.int32(pos), self.cache,
            )
            nxt = (
                jnp.argmax(logits[:, 0, :], -1)
                if greedy
                else jax.random.categorical(jax.random.PRNGKey(pos), logits[:, 0, :])
            ).astype(jnp.int32)
            tok = nxt[:, None]
            stats["step_times"].append(time.time() - t0)
            stats["steps"] += 1
            pos += 1
            for s in range(self.slots):
                a = active[s]
                if a is None:
                    continue
                if not a[1]:                 # prompt done -> emitting
                    a[3].append(int(nxt[s]))
                    a[2] += 1
                    if a[2] >= max_new:
                        results[a[0]] = a[3]
                        active[s] = None
            refill()
        for a in active:
            if a is not None:
                results[a[0]] = a[3]
        return results, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    from repro.configs.registry import get_config

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    loop = ServeLoop(cfg, params, args.slots, max_len=256)

    prompts = [
        list(jax.random.randint(jax.random.fold_in(key, i), (8,), 0, cfg.vocab))
        for i in range(args.requests)
    ]
    t0 = time.time()
    results, stats = loop.run([list(map(int, p)) for p in prompts], args.max_new)
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {1e3*sum(stats['step_times'])/max(stats['steps'],1):.1f} ms/step)")


if __name__ == "__main__":
    main()
