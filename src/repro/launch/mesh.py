"""Production meshes (TPU v5e numbers; DESIGN.md §6).

Functions, not module constants — importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — run under "
            "dryrun.py, which forces XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes
    )


# hardware constants (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (1 link/axis-hop assumed)
HBM_BYTES = 16 * 1024**3        # 16 GiB per chip
