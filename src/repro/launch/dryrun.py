import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh with ShapeDtypeStruct stand-ins (no allocation), and extract the roofline
terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--fast]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json. The two
os.environ lines above MUST stay the first statements in this module — jax
locks the device count on first init (see the build brief).
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.registry import get_config, list_archs
from repro.launch import roofline as RL
from repro.launch import specs as SP
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.parallel import sharding as SH

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def _abstract_opt(params):
    from repro.optim.optimizers import adamw

    init, _ = adamw(1e-4)
    return jax.eval_shape(init, params)


def _ns(mesh, spec_tree, tree):
    specs = SH.sanitize_specs(mesh, spec_tree, tree)
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs
    )


def lower_pair(cfg: ModelConfig, shape: str, mesh, *, fsdp: bool = False,
               donate: bool = True):
    """Returns (lowered, compiled, meta) for one (arch x shape x mesh)."""
    kind = SP.SHAPES[shape]["kind"]
    cfg_eff = SP.effective_pattern(cfg, shape)
    cfg_eff = SP.mesh_adapt(cfg_eff, mesh.shape["model"])

    if kind == "train":
        from repro.launch.train import TrainState, build_train_step

        params = _abstract_params(cfg_eff)
        opt = _abstract_opt(params)
        state = TrainState(params, opt)
        batch = SP.input_specs(cfg_eff, shape)
        pspecs = SH.param_specs(params)
        if fsdp:
            pspecs = _fsdp_specs(pspecs, params)
        ospecs = _fsdp_opt(opt, pspecs) if fsdp else SH.opt_state_specs(opt, params)
        state_specs = TrainState(pspecs, ospecs)
        step = build_train_step(cfg_eff, mesh=mesh)
        jitted = jax.jit(
            step,
            in_shardings=(
                _ns(mesh, state_specs, state),
                _ns(mesh, SH.batch_specs(mesh, batch), batch),
            ),
            donate_argnums=(0,) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(state, batch)

    elif kind == "prefill":
        params = _abstract_params(cfg_eff)
        batch = SP.input_specs(cfg_eff, shape)
        pspecs = SH.param_specs(params)
        if fsdp:
            pspecs = _fsdp_specs(pspecs, params)
        fn = lambda p, b: M.prefill(p, cfg_eff, b, mesh=mesh)
        jitted = jax.jit(
            fn,
            in_shardings=(
                _ns(mesh, pspecs, params),
                _ns(mesh, SH.batch_specs(mesh, batch), batch),
            ),
        )
        with mesh:
            lowered = jitted.lower(params, batch)

    else:  # decode
        params = _abstract_params(cfg_eff)
        token, pos, cache = SP.decode_specs(cfg_eff, shape)
        pspecs = SH.param_specs(params)
        if getattr(cfg_eff, "moe_2d", False):
            pspecs = _moe_2d_specs(pspecs, params)
        if fsdp:
            pspecs = _fsdp_specs(pspecs, params)
        fn = lambda p, t, i, c: M.decode_step(p, cfg_eff, t, i, c, mesh=mesh)
        cache_sh = _ns(mesh, SH.cache_specs(mesh, cache), cache)
        jitted = jax.jit(
            fn,
            in_shardings=(
                _ns(mesh, pspecs, params),
                _ns(mesh, SH.batch_specs(mesh, {"t": token}), {"t": token})["t"],
                None,
                cache_sh,
            ),
            # matching output shardings let XLA alias the donated cache
            # (inferred shardings diverged -> a full extra cache copy, §Perf)
            out_shardings=(None, cache_sh),
            donate_argnums=(3,) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(params, token, pos, cache)

    compiled = lowered.compile()
    return lowered, compiled, {"kind": kind}


def _fsdp_specs(pspecs, params):
    """Add 'data'-axis sharding on the first free dim of >=2D weights (ZeRO-3
    flavoured storage sharding; GSPMD all-gathers at use)."""
    from jax.sharding import PartitionSpec as P

    def leaf(spec, arr):
        dims = list(spec) + [None] * (arr.ndim - len(spec))
        if arr.ndim < 2 or max(arr.shape) < 4096:
            return spec
        if any(d == "data" or (isinstance(d, tuple) and "data" in d) for d in dims):
            return spec  # already data-sharded (e.g. moe_2d expert layout)
        for i, d in enumerate(dims):
            if d is None and arr.shape[i] % 16 == 0:
                dims[i] = "data"
                return P(*dims)
        return spec

    return jax.tree.map(leaf, pspecs, params)


def _moe_2d_specs(pspecs, params):
    """Expert tensors -> experts on 'model' x d_ff on 'data' (matches
    models.moe.moe_ffn_2d's shard_map in_specs)."""
    from jax.sharding import PartitionSpec as P

    def leaf(path, spec, arr):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        if arr.ndim == 4 and name in ("w_gate", "w_up", "w_down") and "ffn" in names:
            # stacked (period, E, d/f, f/d)
            if name == "w_down":
                return P(None, "model", "data", None)
            return P(None, "model", None, "data")
        return spec

    return jax.tree_util.tree_map_with_path(leaf, pspecs, params)


def _fsdp_opt(opt, pspecs):
    from jax.sharding import PartitionSpec as P
    from repro.optim.optimizers import OptState

    return OptState(step=P(), mu=pspecs, nu=pspecs)


def run_pair(arch: str, shape: str, *, multi_pod: bool = False,
             fsdp: bool = False, verbose: bool = True,
             variant: dict | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    if variant:
        cfg = cfg.scaled(**variant)
    skip = SP.shape_skip_reason(cfg, shape)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "fsdp": fsdp, "time_s": 0.0, "variant": variant or {},
    }
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    # >50B models cannot hold params (+optimizer when training) on the model
    # axis alone: 'data'-axis weight sharding is the only sane baseline
    # (noted in EXPERIMENTS.md). With the 2D expert layout the experts are
    # already data-sharded and the residual weights fit — skip blanket FSDP.
    if cfg.param_count() > 50e9 and not getattr(cfg, "moe_2d", False):
        fsdp = True
    rec["fsdp"] = fsdp
    try:
        lowered, compiled, meta = lower_pair(cfg, shape, mesh, fsdp=fsdp)
        cost_naive = compiled.cost_analysis()
        memd = RL.memory_dict(compiled)
        hlo_text = compiled.as_text()
        from repro.launch import hlo_cost

        corrected = hlo_cost.analyze(hlo_text)   # loop-aware (trip counts)
        cost = {
            "flops": corrected["flops"],
            "bytes accessed": corrected["hbm_bytes"],
        }
        coll = {
            "total": corrected["collective_bytes"],
            "counts": corrected["collective_counts"],
        }
        rl = RL.roofline(cost, memd, coll)
        rl["xla_cost_analysis_flops_uncorrected"] = float(cost_naive.get("flops", 0.0))
        mf = RL.model_flops(cfg, SP.SHAPES[shape], meta["kind"])
        hlo_global = rl["hlo_flops_per_dev"] * n_chips
        rec.update(
            status="ok",
            kind=meta["kind"],
            chips=n_chips,
            roofline=rl,
            model_flops_global=mf,
            useful_flops_ratio=(mf / hlo_global) if hlo_global else None,
            fits_hbm=memd["total_hbm_bytes"] <= HBM_BYTES,
            hbm_gib=memd["total_hbm_bytes"] / 1024**3,
            collective_counts=coll["counts"],
            swa_variant=SP.uses_swa_variant(cfg, shape),
        )
        if verbose:
            print(f"  memory_analysis: {memd}")
            print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    rec["time_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", action="append", default=[],
                    help="cfg override key=value (int/bool/float autocast)")
    ap.add_argument("--tag", default=None, help="suffix for the output JSON")
    args = ap.parse_args()

    variant = {}
    for kv in args.variant:
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        variant[k] = v

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    pairs = (
        [(a, s) for a in list_archs() for s in SP.SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    mesh_name = "pod2x16x16" if args.multi_pod else "16x16"
    for arch, shape in pairs:
        tag = f"{arch}__{shape}__{mesh_name}" + ("__fsdp" if args.fsdp else "")
        if args.tag:
            tag += f"__{args.tag}"
        path = OUT_DIR / f"{tag}.json"
        if args.skip_existing and path.exists():
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag}")
        rec = run_pair(arch, shape, multi_pod=args.multi_pod, fsdp=args.fsdp,
                       variant=variant)
        path.write_text(json.dumps(rec, indent=1, default=str))
        status = rec["status"]
        extra = (
            f" dominant={rec['roofline']['dominant']} hbm={rec['hbm_gib']:.1f}GiB"
            if status == "ok" else f" ({rec.get('reason') or rec.get('error', '')[:120]})"
        )
        print(f"  -> {status} in {rec['time_s']}s{extra}")


if __name__ == "__main__":
    main()
