"""Loop-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts each while-loop *body once*, so any
scan-over-layers model is undercounted by its depth (verified: a scan of 5
matmuls reports the flops of 1). This module parses `compiled.as_text()`,
builds the computation call graph (while bodies carry
`backend_config={"known_trip_count":...}`), and propagates multipliers to
produce loop-corrected:

  * dot/convolution FLOPs            (2 * prod(result) * contraction size)
  * collective link bytes            (result bytes; all-reduce weighted 2x for
                                      the ring's reduce+broadcast phases)
  * HBM bytes (approximate)          (sum of result+operand bytes over
                                      top-level instructions, fusion-internal
                                      ops excluded)

Elementwise FLOPs are ignored (dot-dominated workloads); the HBM byte count
is a structural estimate — fusion boundaries on the CPU backend differ from
TPU, so treat it as an upper-ish bound. Documented in EXPERIMENTS.md §Method.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[sufc]\d+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(([^)]*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLEE = re.compile(
    r"(?:body|to_apply|calls)=%?([\w\.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COND_TF = re.compile(r"(?:true_computation|false_computation)=%?([\w\.\-]+)")

COLLECTIVES = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0, "ragged-all-to-all": 1.0,
}

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
}


def _shapes_bytes(text: str) -> int:
    return sum(
        _prod(dims) * _DTYPE_BYTES.get(dt, 4)
        for dt, dims in _SHAPE_RE.findall(text)
    )


def _prod(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), [int(d) for d in m.group(2).split(",") if d]


_OPERAND_NAME = re.compile(r"%?([\w\.\-]+)$")


def _split_operands(text: str) -> list:
    """Split an HLO operand list on top-level commas and keep only the
    operand *names*.

    Operand tokens carry their full type text (``f32[256,256]{1,0} %p``), so a
    naive ``split(",")`` shreds tokens on shape commas and the resulting
    strings never match the computation's shape table — downstream consumers
    (`_dot_flops` contraction size, HBM operand bytes) silently fall back to
    empty shapes. Track bracket depth across ``([{`` and take the trailing
    identifier of each token.
    """
    out = []
    depth = 0
    tok = ""
    for ch in text + ",":
        if ch == "," and depth == 0:
            m = _OPERAND_NAME.search(tok.strip())
            if m:
                out.append(m.group(1))
            tok = ""
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        tok += ch
    return out


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_text: str
    body: str
    operands: list


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: list[Instr] = []
        self.shapes: dict[str, str] = {}   # instr/param name -> shape text


_OPCODE_RE = re.compile(r"(?:\)|\]|\})?\s*([a-z][\w\-]*)\(")


def _parse_header(line: str):
    """'%name (p: t, ...) -> type {' with nested parens -> (name, params_text)."""
    s = line.strip()
    if not s.endswith("{") or "->" not in s:
        return None
    if s.startswith("ENTRY "):
        s = s[len("ENTRY "):].lstrip()
    m = re.match(r"%?([\w\.\-]+)\s*\(", s)
    if not m:
        return None
    name = m.group(1)
    depth = 0
    start = s.index("(")
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                inner = s[start + 1 : i]
                if "->" not in s[i:]:
                    return None
                return name, inner
    return None


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _parse_header(line)
        if hdr:
            cur = Computation(hdr[0])
            comps[cur.name] = cur
            # parameter shapes from the header (top-level comma split)
            depth = 0
            cur_tok = ""
            toks = []
            for ch in hdr[1]:
                if ch == "," and depth == 0:
                    toks.append(cur_tok)
                    cur_tok = ""
                    continue
                if ch in "([{":
                    depth += 1
                elif ch in ")]}":
                    depth -= 1
                cur_tok += ch
            if cur_tok.strip():
                toks.append(cur_tok)
            for t in toks:
                if ":" in t:
                    pname, ptype = t.split(":", 1)
                    cur.shapes[pname.strip().lstrip("%")] = ptype.strip()
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # result type = everything before the opcode's '('
        om = re.search(r"\b([a-z][\w\-]*)\(", rest)
        opcode = om.group(1) if om else ""
        result_text = rest[: om.start()] if om else rest
        # operands: inside the first (...) after opcode
        operands = []
        if om:
            depth = 0
            start = om.end()
            for i in range(start, len(rest)):
                c = rest[i]
                if c == "(":
                    depth += 1
                elif c == ")":
                    if depth == 0:
                        operands = _split_operands(rest[start:i])
                        break
                    depth -= 1
        cur.shapes[name] = result_text
        cur.instrs.append(Instr(name, opcode, result_text, rest, operands))
    return comps


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    entry = None
    for name in comps:
        if name.startswith("main") or entry is None:
            if entry is None or name.startswith("main"):
                entry = name
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(64):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                trip = 1.0
                if ins.opcode == "while":
                    t = _TRIP.search(ins.body)
                    trip = float(t.group(1)) if t else 1.0
                for cm in _CALLEE.finditer(ins.body):
                    new[cm.group(1)] += m * trip
                for cm in _COND_TF.finditer(ins.body):
                    new[cm.group(1)] += m
                bm = _BRANCHES.search(ins.body)
                if bm:
                    for b in bm.group(1).split(","):
                        new[b.strip().lstrip("%")] += m
                # condition computation of while
                cond = re.search(r"condition=%?([\w\.\-]+)", ins.body)
                if cond:
                    new[cond.group(1)] += m * trip
        if dict(new) != dict(mult):
            mult = new
            changed = True
        if not changed:
            break
    return dict(mult)


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out = _first_shape(ins.result_text)
    if out is None or not ins.operands:
        return 0.0
    _, out_dims = out
    lhs_shape = _first_shape(comp.shapes.get(ins.operands[0], ""))
    k = 1
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.body)
    if cm and lhs_shape:
        _, ldims = lhs_shape
        for d in cm.group(1).split(","):
            if d:
                i = int(d)
                if i < len(ldims):
                    k *= ldims[i]
    return 2.0 * _prod(",".join(map(str, out_dims)) if out_dims else "") * k


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    mult = _multipliers(comps)
    fusion_names = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                for cm in _CALLEE.finditer(ins.body):
                    fusion_names.add(cm.group(1))

    flops = 0.0
    coll_bytes = 0.0
    coll_counts: dict[str, float] = defaultdict(float)
    hbm_bytes = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        inside_fusion = cname in fusion_names
        for ins in comp.instrs:
            if ins.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(comp, ins)
            for kind, w in COLLECTIVES.items():
                if ins.opcode == kind or ins.opcode == kind + "-start":
                    b = _shapes_bytes(ins.result_text)
                    coll_bytes += m * w * b
                    coll_counts[kind] += m
            if not inside_fusion and ins.opcode not in _SKIP_BYTES_OPS:
                io = _shapes_bytes(ins.result_text)
                for op in ins.operands:
                    io += _shapes_bytes(comp.shapes.get(op, ""))
                hbm_bytes += m * io
    return {
        "flops": flops,
        "collective_bytes": coll_bytes,
        "collective_counts": dict(coll_counts),
        "hbm_bytes": hbm_bytes,
        "n_computations": len(comps),
    }
