"""The closed FedSem loop, end to end: FL-trained SemCom jobs served by the
live allocation stack.

  PYTHONPATH=src python -m repro.launch.fedsem_e2e --smoke
  PYTHONPATH=src python -m repro.launch.fedsem_e2e --jobs 3 --rounds 6

Four phases, one shared compiled-executable cache:

1. **Backend equivalence** (gates exit): for the same round scenarios and
   the same `AllocatorConfig`, the `ServiceBackend` over a virtual-clock
   `AllocService` must return the EXACT hardened assignment X that the
   offline `PlannedBackend` computes, round for round — the guarantee that
   routing `run_fl` through the serving stack changes scheduling, never
   answers (`repro.fl.alloc_backend`). Since the service rides accuracy as
   a stacked per-row runtime argument, this is also the uniform-tenant
   batched-acc == replicated-acc equivalence row, gated end to end.
2. **Feedback loop** (gates exit): one `SemComJob` trains the real
   autoencoder over the virtual-clock service; its proxy-accuracy
   measurements must produce an applied A(rho) refit whose curve is
   monotone nondecreasing on a rho grid (Assumption 1 survives the refit).
3. **Multi-job serving** (gates completeness only): J concurrent
   heterogeneous FL jobs — different scenario families (`hetero_classes`,
   `gauss_markov`, ...), sizes and seeds — share ONE `RealClockDriver`,
   each under its OWN tenant id; their per-round requests co-batch inside
   the service and every job's accuracy/energy trajectory plus the
   service-side p95/occupancy are reported (`benchmarks.bench_fedsem`
   turns them into BENCH rows).
4. **Multi-tenant non-interference** (gates exit): each phase-3 job is
   re-run ALONE — same seed, same tenant id, a fresh virtual-clock service —
   and its full trajectory (per-round loss/rho/energy/objective and every
   proxy-accuracy measurement) must match its co-tenanted run exactly.
   A(rho) refits are per-tenant runtime state and co-batched rows are
   independent under vmap, so sharing a driver with other feedback-pushing
   jobs changes NOTHING about a job's own answers — the mixed-tenant
   as-if-alone equivalence row, gated end to end.

Phases 1–2 run with ``feedback`` disabled where it would break determinism:
a refit mid-run is the POINT of phase 2 but would make phase 1's planned
and served answers diverge, so the equivalence check speaks below `run_fl`,
directly to the backends.
"""
from __future__ import annotations

import argparse
import json
import sys
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AllocatorConfig, Weights, tree_bits
from repro.core.pgd import PGDConfig
from repro.fl import (
    FLConfig,
    PlannedBackend,
    SemComJob,
    SemComJobConfig,
    SemComJobResult,
    ServiceBackend,
    sample_round_scenarios,
    serve_config_for,
)
from repro.semcom import AEConfig, init_params
from repro.serve import AllocService, BatchPolicy, RealClockDriver
from repro.serve.service import ServeConfig

#: (name, scenario family, n_clients, n_subcarriers) per concurrent job —
#: heterogeneous on purpose: different populations, channels and shapes,
#: one allocation service
JOB_SPECS = (
    ("hetero", "hetero_classes", 4, 12),
    ("markov", "gauss_markov", 4, 12),
    ("iid", "iid_rayleigh", 6, 16),
)
JOB_SPECS_SMOKE = (
    ("hetero", "hetero_classes", 3, 8),
    ("markov", "gauss_markov", 4, 8),
)


def harness_config(smoke: bool, rounds: int | None, jobs: int | None):
    """Shared knobs for CLI and benchmark: allocator, serve policy, job specs,
    AE size. Smoke shrinks everything to CI scale (same reduced allocator as
    `serve_alloc --smoke`)."""
    if smoke:
        allocator = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
        specs = JOB_SPECS_SMOKE
        rounds = 3 if rounds is None else rounds
        ae = AEConfig(image_size=16, hidden=4, base_latent=4)
        batch, eval_batch = 4, 8
    else:
        allocator = AllocatorConfig(inner="pgd")
        specs = JOB_SPECS
        rounds = 6 if rounds is None else rounds
        ae = AEConfig(image_size=32, hidden=8, base_latent=8)
        batch, eval_batch = 8, 16
    if jobs is not None:
        specs = tuple(specs[i % len(specs)] for i in range(jobs))
    serve_cfg = serve_config_for(
        allocator, policy=BatchPolicy(max_batch=4, max_wait_s=0.02)
    )
    return allocator, serve_cfg, specs, rounds, ae, batch, eval_batch


def make_job(spec, rounds: int, ae: AEConfig, batch: int, eval_batch: int,
             feedback: bool = True) -> SemComJob:
    name, family, n, k = spec
    return SemComJob(
        SemComJobConfig(
            fl=FLConfig(
                n_clients=n, n_subcarriers=k, rounds=rounds, local_steps=2,
                scenario=family,
            ),
            ae=ae,
            batch_size=batch,
            eval_batch=eval_batch,
            feedback=feedback,
            name=name,
        )
    )


def check_backend_equivalence(
    key: jax.Array, fl_cfg: FLConfig, allocator: AllocatorConfig,
    serve_cfg: ServeConfig, d_bits: float, executables: dict,
) -> dict:
    """Phase 1: PlannedBackend vs virtual-clock ServiceBackend on identical
    round scenarios — hardened X must match exactly, rho to float32."""
    w = Weights.ones()
    scenarios = sample_round_scenarios(key, fl_cfg, d_bits)
    planned = PlannedBackend(allocator)
    planned.open(scenarios, w)
    served = ServiceBackend(AllocService(serve_cfg, executables=executables))
    served.open(scenarios, w)
    x_equal, rho_close = True, True
    rhos = []
    for rnd in range(fl_cfg.rounds):
        a, b = planned.allocate(rnd), served.allocate(rnd)
        x_equal &= bool(np.array_equal(np.asarray(a.X), np.asarray(b.X)))
        rho_close &= bool(np.allclose(float(a.rho), float(b.rho), atol=1e-6))
        rhos.append(float(a.rho))
    return {
        "rounds": fl_cfg.rounds,
        "rho_planned": rhos,
        "hardened_x_equal": x_equal,
        "rho_allclose": rho_close,
        "equivalent": x_equal and rho_close,
    }


def run_refit_loop(
    key: jax.Array, job: SemComJob, serve_cfg: ServeConfig, executables: dict,
) -> tuple[SemComJobResult, dict]:
    """Phase 2: one SemComJob over the virtual-clock service with feedback on.
    Gate: a refit was applied and its A(rho) is monotone on a rho grid."""
    backend = ServiceBackend(AllocService(serve_cfg, executables=executables))
    result = job.run(key, backend)
    fit = result.accuracy_fit
    grid = jnp.linspace(0.05, 1.0, 20)
    vals = np.asarray(fit.value(grid)) if fit is not None else np.zeros(1)
    monotone = bool(np.all(np.diff(vals) >= -1e-7))
    return result, {
        "refit_applied": result.refit_applied,
        "refit_round": result.refit_round,
        "fit_a": float(fit.a) if fit is not None else None,
        "fit_b": float(fit.b) if fit is not None else None,
        "fit_monotone": monotone,
        "n_measurements": len(result.measurements),
        "ok": bool(result.refit_applied and monotone),
    }


def tenant_id(job: SemComJob, i: int) -> str:
    """One tenant id per concurrent job slot (names repeat when ``--jobs``
    cycles the spec table, so the slot index disambiguates)."""
    return f"{job.cfg.name}:{i}"


def run_multijob(
    key: jax.Array, jobs: list[SemComJob], serve_cfg: ServeConfig,
    executables: dict,
) -> tuple[list[SemComJobResult], dict]:
    """Phase 3: every job in its own thread, one shared `RealClockDriver`,
    each under its own tenant id.

    The service is warmed on each job's round-0 scenario first so the solver
    thread never pays a compile mid-serve; same-bucket jobs then co-batch.
    The A(rho) refits the jobs push are PER-TENANT: each backend scopes its
    `set_accuracy` to its own tenant registry entry, so co-tenants keep
    their own beliefs (phase 4 gates this bit-for-bit).
    """
    warm = []
    for i, job in enumerate(jobs):
        fl = job.cfg.fl
        d_bits = tree_bits(init_params(jax.random.PRNGKey(0), job.ae))
        warm.append(
            sample_round_scenarios(jax.random.fold_in(key, i), fl, d_bits)[0]
        )
    service = AllocService(serve_cfg, executables=executables)
    service.warmup(warm)
    with RealClockDriver(service) as driver:
        with ThreadPoolExecutor(max_workers=len(jobs)) as pool:
            futs = [
                pool.submit(
                    job.run,
                    jax.random.fold_in(key, i),
                    ServiceBackend(driver, tenant=tenant_id(job, i)),
                )
                for i, job in enumerate(jobs)
            ]
            results = [f.result() for f in futs]
        driver.close(timeout=600.0)
        summary = driver.summary()
    return results, summary


def check_noninterference(
    key: jax.Array, jobs: list[SemComJob], co_results: list[SemComJobResult],
    serve_cfg: ServeConfig, executables: dict,
) -> dict:
    """Phase 4: re-run each phase-3 job ALONE (same seed/tenant, fresh
    virtual-clock service) and require its trajectory to match the
    co-tenanted run exactly — co-tenants' feedback must not leak.

    Exactness is justified, not hoped for: a request solves and scores under
    the A(rho) fit stamped at its OWN admission (per-tenant registry), and
    co-batched rows are independent under vmap, so the only thing sharing a
    driver changes is scheduling. ``key`` must be the phase-3 key (the solo
    runs re-derive the same per-job fold)."""
    per_job = []
    for i, (job, co) in enumerate(zip(jobs, co_results)):
        backend = ServiceBackend(
            AllocService(serve_cfg, executables=executables),
            tenant=tenant_id(job, i),
        )
        solo = job.run(jax.random.fold_in(key, i), backend)
        rounds_equal = len(co.history) == len(solo.history) and all(
            a.loss == b.loss and a.rho == b.rho and a.energy == b.energy
            and a.t_fl == b.t_fl and a.objective == b.objective
            for a, b in zip(co.history, solo.history)
        )
        meas_equal = co.measurements == solo.measurements
        per_job.append(
            {
                "job": co.name,
                "tenant": tenant_id(job, i),
                "trajectory_equal": bool(rounds_equal),
                "measurements_equal": bool(meas_equal),
            }
        )
    ok = all(j["trajectory_equal"] and j["measurements_equal"] for j in per_job)
    return {"jobs": per_job, "ok": bool(ok)}


def trajectory(result: SemComJobResult) -> dict:
    """One job's fig8-style accuracy/energy trajectory (per-round rows)."""
    return {
        "job": result.name,
        "rounds": len(result.history),
        "loss": [h.loss for h in result.history],
        "rho": [h.rho for h in result.history],
        "energy": [h.energy for h in result.history],
        "t_fl": [h.t_fl for h in result.history],
        "objective": [h.objective for h in result.history],
        "proxy_accuracy": [
            a for _, a in result.measurements
        ],
        "refit_applied": result.refit_applied,
        "refit_round": result.refit_round,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=None,
                    help="concurrent FL jobs in phase 3 (default: all specs)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: tiny AE, reduced allocator, 2 jobs")
    args = ap.parse_args()

    allocator, serve_cfg, specs, rounds, ae, batch, eval_batch = harness_config(
        args.smoke, args.rounds, args.jobs
    )
    key = jax.random.PRNGKey(args.seed)
    executables: dict = {}

    # phase 1: equivalence at the backend level (feedback would break it)
    probe = make_job(specs[0], rounds, ae, batch, eval_batch)
    d_bits = tree_bits(init_params(jax.random.PRNGKey(0), probe.ae))
    eq = check_backend_equivalence(
        jax.random.fold_in(key, 100), probe.cfg.fl, allocator, serve_cfg,
        d_bits, executables,
    )
    print(f"[1/4] backend equivalence over {eq['rounds']} rounds: "
          f"hardened X equal = {eq['hardened_x_equal']}, "
          f"rho allclose = {eq['rho_allclose']}")

    # phase 2: the feedback edge through the virtual-clock service
    result, refit = run_refit_loop(
        jax.random.fold_in(key, 200), make_job(specs[0], rounds, ae, batch, eval_batch),
        serve_cfg, executables,
    )
    print(f"[2/4] refit: applied = {refit['refit_applied']} "
          f"(round {refit['refit_round']}), "
          f"A(rho) = {refit['fit_a']} * rho^{refit['fit_b']}, "
          f"monotone = {refit['fit_monotone']}")

    # phase 3: J heterogeneous jobs, one real-clock driver
    key3 = jax.random.fold_in(key, 300)
    jobs = [make_job(s, rounds, ae, batch, eval_batch) for s in specs]
    results, summary = run_multijob(key3, jobs, serve_cfg, executables)
    completed = all(len(r.history) == rounds for r in results)
    print(f"[3/4] {len(results)} concurrent jobs x {rounds} rounds over one "
          f"driver: all completed = {completed}, "
          f"p95 latency = {summary.get('latency_p95_s', 0) * 1e3:.1f}ms, "
          f"occupancy = {summary.get('batch_occupancy_mean', 0):.2f}")

    # phase 4: each job re-run alone must reproduce its co-tenanted
    # trajectory exactly — per-tenant A(rho) refits never leak
    nonint = check_noninterference(key3, jobs, results, serve_cfg, executables)
    print(f"[4/4] multi-tenant non-interference over {len(jobs)} jobs: "
          f"as-if-alone = {nonint['ok']}")
    print(json.dumps(
        {
            "equivalence": eq,
            "refit": refit,
            "jobs": [trajectory(r) for r in results],
            "noninterference": nonint,
            "service": summary,
        },
        indent=2,
    ))
    ok = eq["equivalent"] and refit["ok"] and completed and nonint["ok"]
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
