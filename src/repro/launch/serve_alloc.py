"""Scenario-allocation serving driver: Poisson load over `AllocService`.

  PYTHONPATH=src python -m repro.launch.serve_alloc --requests 32 --rate 20
  PYTHONPATH=src python -m repro.launch.serve_alloc --driver real --ladder learned --smoke
  PYTHONPATH=src python -m repro.launch.serve_alloc --driver real --scenario gauss_markov --ladder auto --smoke
  PYTHONPATH=src python -m repro.launch.serve_alloc --driver real --scenario gauss_markov --warmstart --smoke

Generates a mixed-size scenario stream (shared per-subcarrier bandwidth so
sizes co-batch in one `ShapeBucket`) from any registered scenario family
(``--scenario``; ``gauss_markov`` gives time-correlated fading instead of
i.i.d. redraws per request), warms the compiled-solver cache, and drives the
micro-batched service two ways:

  * ``--driver virtual`` (default) — the reproducible discrete-event
    simulation: Poisson arrivals on a virtual clock, solves charged at
    measured wall time (`repro.serve.loadgen`).
  * ``--driver real``    — the threaded real-clock front-end
    (`repro.serve.driver.RealClockDriver`): this process paces arrivals with
    real sleeps and submits from the main thread while the solver thread
    overlaps flushes; shutdown drains every queue. With ``--smoke`` the same
    stream is then replayed through the virtual-clock loadgen and the
    hardened assignments must match request-for-request (exit 1 otherwise) —
    the CI gate on the driver's equivalence contract.

``--ladder learned`` fits an autoscaling bucket ladder to the stream's
observed (N, K) mix (`repro.serve.ladder`) instead of `DEFAULT_BUCKETS` and
prints the predicted padded-area waste of both. ``--ladder auto`` (real
driver only) starts from `DEFAULT_BUCKETS` and lets the driver's solver
thread refit online when the observed mix's padded waste drifts past
`DriverConfig.refit_waste_threshold` — no pre-fit pass over the stream.
``--policy exact --max-batch 1`` degenerates to the solve-per-request
baseline the serving benchmark compares against.

``--warmstart`` enables the warm-start solution-reuse cache
(`repro.serve.warmstart`): each completed request's hardened solution is
recorded under a quantized channel/accuracy signature, and later requests
with a colliding signature ride it as an extra multi-start candidate —
never-worse objectives (dominance), with cache hit/miss accounting in the
summary. Under ``--driver real --smoke`` the equivalence replay re-injects
the recorded per-request starts, so the exact-X gate covers warm runs too.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.core import DEFAULT_BUCKETS, AllocatorConfig
from repro.core.pgd import PGDConfig
from repro.core.system import feasible
from repro.scenarios import list_families
from repro.serve import (
    AllocService,
    BatchPolicy,
    DriverConfig,
    LadderLearner,
    RealClockDriver,
    ServeConfig,
    WarmStartConfig,
    pace_stream,
    poisson_arrivals,
    run_load,
    same_hardened_assignments,
    scenario_stream,
)


def build_config(args, buckets) -> ServeConfig:
    if args.smoke:
        allocator = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
    else:
        allocator = AllocatorConfig(inner=args.inner)
    return ServeConfig(
        policy=BatchPolicy(max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3),
        buckets=buckets,
        allocator=allocator,
        shard_batch=args.shard,
        warmstart=WarmStartConfig() if args.warmstart else None,
    )


def fit_ladder(args, requests):
    """Resolve the bucket ladder for this run (None = exact shapes)."""
    if args.policy == "exact":
        if args.ladder != "fixed":
            print(f"--policy exact serves exact shapes; --ladder {args.ladder} ignored")
        return None
    if args.ladder in ("fixed", "auto"):
        # auto starts from the defaults; the driver refits online on drift
        return DEFAULT_BUCKETS
    learner = LadderLearner(min_samples=1)
    for p in requests:
        learner.observe(p.N, p.K)
    snap = learner.refit()
    print(
        f"learned ladder from {snap.n_observed} shapes: "
        f"{[(b.N, b.K) for b in snap.buckets]}\n"
        f"predicted padded-area waste: learned {snap.waste:.3f} "
        f"vs DEFAULT_BUCKETS {snap.baseline_waste:.3f}"
    )
    return snap.buckets


def drive_real(service, requests, arrivals, args) -> tuple[list, float]:
    """Pace the stream on the real clock through a `RealClockDriver`.

    ``--ladder auto`` attaches a `LadderLearner` plus the auto-refit
    thresholds, so the solver thread re-learns the bucket ladder mid-stream
    when the observed shape mix drifts. Otherwise no learner is attached:
    when ``--ladder learned`` the ladder was already fit on this same
    stream's shapes, and the driver observing them again would double-weight
    the prefix in any later refit."""
    if args.ladder == "auto" and args.policy != "exact":
        check = 4 if args.smoke else 64
        driver = RealClockDriver(
            service,
            cfg=DriverConfig(
                refit_waste_threshold=0.15,
                refit_check_every=check,
                refit_min_samples=check,
            ),
            ladder=LadderLearner(min_samples=1),
        )
    else:
        driver = RealClockDriver(service)
    futures, t_start = pace_stream(driver, requests, arrivals)
    driver.close(timeout=300.0)
    makespan = driver.now() - t_start
    completions = [f.result(timeout=0.0) for f in futures]  # resolved by drain
    if driver.ladder is not None:
        print(
            f"auto-refits: {driver.auto_refits}; serving ladder now "
            f"{[(b.N, b.K) for b in service.cfg.buckets]}"
        )
    return completions, makespan


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=20.0, help="arrival rate [req/s]")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--policy", choices=("ladder", "exact"), default="ladder")
    ap.add_argument(
        "--driver",
        choices=("virtual", "real"),
        default="virtual",
        help="virtual: reproducible DES clock; real: threaded real-clock "
        "driver with paced arrivals (and, under --smoke, a virtual-clock "
        "equivalence replay that gates the exit status)",
    )
    ap.add_argument(
        "--ladder",
        choices=("fixed", "learned", "auto"),
        default="fixed",
        help="fixed: DEFAULT_BUCKETS; learned: fit the bucket ladder to the "
        "stream's observed (N, K) mix before serving; auto: start fixed and "
        "let the real-clock driver refit online on shape-mix drift "
        "(--driver real only)",
    )
    ap.add_argument(
        "--scenario",
        choices=list_families(),
        default="iid_rayleigh",
        help="registered scenario family the request stream is drawn from "
        "(gauss_markov: time-correlated fading across requests)",
    )
    ap.add_argument("--inner", choices=("pgd", "sca", "auto"), default="pgd")
    ap.add_argument(
        "--warmstart",
        action="store_true",
        help="enable the warm-start solution-reuse cache "
        "(repro.serve.warmstart): completed hardened solutions re-enter "
        "later solves as an extra multi-start candidate — never-worse "
        "objectives by the dominance invariant, best paired with "
        "--scenario gauss_markov (time-correlated channels produce hits)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="tiny allocator + stream")
    ap.add_argument(
        "--shard",
        action="store_true",
        help="shard each flush over all local devices (scenario mesh); "
        "--max-batch becomes the per-device batch. Combine with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8 to try it on CPU",
    )
    args = ap.parse_args()
    if args.ladder == "auto" and args.driver != "real":
        ap.error("--ladder auto needs --driver real (online refit lives in "
                 "the real-clock driver's solver thread)")

    key = jax.random.PRNGKey(args.seed)
    sizes = ((3, 8), (4, 8)) if args.smoke else ((3, 8), (4, 12), (6, 16))
    n = min(args.requests, 8) if args.smoke else args.requests
    requests = scenario_stream(key, n, scenario=args.scenario, sizes=sizes)
    arrivals = poisson_arrivals(jax.random.fold_in(key, 1), n, args.rate)

    buckets = fit_ladder(args, requests)
    cfg = build_config(args, buckets)
    service = AllocService(cfg)
    if service.mesh is not None:
        print(
            f"scenario mesh: {service.mesh.size} device(s), "
            f"{service.cfg.policy.max_batch} slots each"
        )
    print(f"warming compiled-solver cache for {len(set(sizes))} shapes ...")
    service.warmup(requests)

    if args.driver == "real":
        completions, makespan = drive_real(service, requests, arrivals, args)
        summary = service.metrics.summary()
        busy = service.metrics.solves_s.total     # exact even past the cap
    else:
        result = run_load(service, requests, arrivals)
        completions, makespan, busy = result.completions, result.makespan_s, result.busy_s
        summary = result.summary

    n_feas = sum(
        bool(feasible(requests[c.req_id], c.alloc)) for c in completions
    )
    if service.warm_cache is not None:
        summary = {**summary, **service.warm_cache.stats()}
    print(json.dumps(summary, indent=2))
    print(
        f"served {len(completions)}/{n} requests "
        f"({n_feas} feasible) in {makespan:.3f}s {args.driver} "
        f"({busy:.3f}s solving) -> {len(completions) / max(makespan, 1e-9):.1f} req/s"
    )
    ok = len(completions) == n and n_feas == n

    if args.driver == "real" and args.smoke:
        # equivalence gate: replay the same stream on the virtual clock (same
        # config, shared executable cache) — the hardened assignment of every
        # request must match the real-clock driver's answer exactly. With
        # --warmstart, cache contents are timing-dependent (batch boundaries
        # move which entries exist at each lookup), so the replay re-injects
        # the RECORDED per-request warm starts into a cache-disabled service
        # — same inputs, so still exact X equality
        replay_cfg = cfg._replace(warmstart=None)
        starts = None
        if args.warmstart:
            by_id = {c.req_id: c for c in completions}
            starts = [by_id[i].warm_start for i in range(len(requests))]
        replay = run_load(
            AllocService(replay_cfg, executables=service.executables),
            requests,
            arrivals,
            warm_starts=starts,
        )
        same = same_hardened_assignments(completions, replay.completions)
        print(
            f"real-vs-virtual equivalence (exact hardened X, "
            f"{len(completions)} reqs): {same}"
        )
        ok = ok and same

    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
