"""Scenario-allocation serving driver: Poisson load over `AllocService`.

  PYTHONPATH=src python -m repro.launch.serve_alloc --requests 32 --rate 20
  PYTHONPATH=src python -m repro.launch.serve_alloc --smoke

Generates a mixed-size scenario stream (shared per-subcarrier bandwidth so
sizes co-batch in one `ShapeBucket`), warms the compiled-solver cache, drives
the micro-batched service with Poisson arrivals on the virtual clock, and
prints throughput plus p50/p95 latency, queue-depth and batch-occupancy
stats. ``--policy exact --max-batch 1`` degenerates to the solve-per-request
baseline the benchmark compares against.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.core import DEFAULT_BUCKETS, AllocatorConfig, sample_request_stream
from repro.core.pgd import PGDConfig
from repro.core.system import feasible
from repro.serve import AllocService, BatchPolicy, ServeConfig, poisson_arrivals, run_load


def build_config(args) -> ServeConfig:
    if args.smoke:
        allocator = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
    else:
        allocator = AllocatorConfig(inner=args.inner)
    return ServeConfig(
        policy=BatchPolicy(max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3),
        buckets=None if args.policy == "exact" else DEFAULT_BUCKETS,
        allocator=allocator,
        shard_batch=args.shard,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=20.0, help="arrival rate [req/s]")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--policy", choices=("ladder", "exact"), default="ladder")
    ap.add_argument("--inner", choices=("pgd", "sca", "auto"), default="pgd")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="tiny allocator + stream")
    ap.add_argument(
        "--shard",
        action="store_true",
        help="shard each flush over all local devices (scenario mesh); "
        "--max-batch becomes the per-device batch. Combine with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8 to try it on CPU",
    )
    args = ap.parse_args()

    key = jax.random.PRNGKey(args.seed)
    sizes = ((3, 8), (4, 8)) if args.smoke else ((3, 8), (4, 12), (6, 16))
    n = min(args.requests, 8) if args.smoke else args.requests
    requests = sample_request_stream(key, n, sizes=sizes)
    arrivals = poisson_arrivals(jax.random.fold_in(key, 1), n, args.rate)

    service = AllocService(build_config(args))
    if service.mesh is not None:
        print(
            f"scenario mesh: {service.mesh.size} device(s), "
            f"{service.cfg.policy.max_batch} slots each"
        )
    print(f"warming compiled-solver cache for {len(set(sizes))} shapes ...")
    service.warmup(requests)
    result = run_load(service, requests, arrivals)

    n_feas = sum(
        bool(feasible(requests[c.req_id], c.alloc)) for c in result.completions
    )
    print(json.dumps(result.summary, indent=2))
    print(
        f"served {len(result.completions)}/{n} requests "
        f"({n_feas} feasible) in {result.makespan_s:.3f}s virtual "
        f"({result.busy_s:.3f}s solving) -> {result.throughput_rps:.1f} req/s"
    )


if __name__ == "__main__":
    main()
