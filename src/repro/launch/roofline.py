"""Roofline extraction from compiled dry-run artifacts (DESIGN.md §7).

All numbers are per-device (the compiled module IS the per-device program
after SPMD partitioning), so each term is directly a time lower bound:

  compute    = HLO_FLOPs_per_device / 197 TFLOP/s
  memory     = HLO_bytes_per_device / 819 GB/s
  collective = collective_bytes_per_device / 50 GB/s per link

collective_bytes is not in cost_analysis(): we parse the post-partitioning
HLO text and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op.
"""
from __future__ import annotations

import re

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"\b(pred|[sufc]\d+|bf16)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")\b"
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from compiled HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if f"{kind}-done" in line:   # async pair: count the -start only
            continue
        # result shape(s) live between '=' and the op name
        seg = line.split(" = ", 1)[1] if " = " in line else line
        seg = seg.split(kind)[0]
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(seg))
        out[kind] += total
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def roofline(cost: dict, mem: dict, coll: dict) -> dict:
    """Three-term per-device roofline (seconds) + dominant bottleneck."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    bytes_coll = float(coll.get("total", 0))
    terms = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": bytes_coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_hbm,
        "collective_bytes_per_dev": bytes_coll,
        "memory_analysis": mem,
    }


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        out[k] = int(getattr(ma, k, 0) or 0)
    out["total_hbm_bytes"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out["alias_size_in_bytes"]
    )
    return out


def model_flops(cfg, shape_info: dict, kind: str) -> float:
    """MODEL_FLOPS: 6*N_active*tokens (train) or 2*N_active*tokens (serve),
    GLOBAL (multiply ratios accordingly)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 2.0 * n_active * tokens
    tokens = shape_info["batch"]  # decode: one token per sequence
    return 2.0 * n_active * tokens
