"""Input ShapeDtypeStructs per (architecture x input shape) — no allocation.

Shapes (assigned):
  train_4k     seq 4,096    global_batch 256   (training)
  prefill_32k  seq 32,768   global_batch 32    (inference-prefill)
  decode_32k   seq 32,768   global_batch 128   (inference-decode: 1 new token)
  long_500k    seq 524,288  global_batch 1     (long-context decode)

Skips (DESIGN.md §5): hubert has no decode shapes (encoder-only); the pure
full-attention decoders (starcoder2 / qwen2.5 / pixtral) run long_500k only as
their sliding-window variant, which their configs enable.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def shape_skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    kind = SHAPES[shape]["kind"]
    if cfg.arch_type == "audio" and kind == "decode":
        return "encoder-only: no decode step (DESIGN.md §5)"
    if shape == "long_500k":
        full_attn = (
            cfg.block_pattern == ("attn",) and cfg.sliding_window is None
        )
        if full_attn:
            return "pure full attention without SWA variant (DESIGN.md §5)"
    return None


def uses_swa_variant(cfg: ModelConfig, shape: str) -> bool:
    """Dense full-attention archs run long_500k with their SWA variant."""
    return (
        shape == "long_500k"
        and cfg.block_pattern == ("attn",)
        and cfg.sliding_window is not None
        and cfg.arch_type in ("dense", "vlm")
    )


def effective_pattern(cfg: ModelConfig, shape: str) -> ModelConfig:
    """long_500k on full-attention dense archs -> all-local (SWA) variant."""
    if uses_swa_variant(cfg, shape):
        return cfg.scaled(block_pattern=("attn_local",))
    return cfg


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


def mesh_adapt(cfg: ModelConfig, model_axis: int) -> ModelConfig:
    """Pad q heads / replicate kv heads so head axes divide the model axis.

    Zero-padded q heads and repeat-interleaved kv heads compute the *same
    function* as the original GQA layout (zero heads contribute nothing
    through wo; each q group still sees its original kv head) — the classic
    TPU answer to head counts like arctic's 56 on a 16-way tensor-parallel
    mesh. The padding overhead is surfaced by the MODEL_FLOPS/HLO_FLOPs ratio
    in §Roofline (DESIGN.md §6).
    """
    if cfg.use_mla or not any(k.startswith("attn") for k in cfg.block_pattern):
        return cfg
    H, KV = cfg.n_heads, cfg.n_kv_heads
    H_pad = -(-H // model_axis) * model_axis if H % model_axis else H
    KV_eff = _lcm(KV, model_axis)
    if KV_eff > H_pad:
        KV_eff = H_pad
    if H_pad % KV_eff:
        KV_eff = H_pad  # degenerate: go MHA
    if H_pad == H and KV_eff == KV:
        return cfg
    return cfg.scaled(n_heads=H_pad, n_kv_heads=KV_eff, head_dim=cfg.hd)


def input_specs(cfg: ModelConfig, shape: str) -> dict[str, Any]:
    """Batch ShapeDtypeStructs for train/prefill entry points."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), jnp.bfloat16),
            "labels": tok,
            "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
        }
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend == "vision":
        n_patch = min(1024, S // 4)
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, n_patch, cfg.frontend_dim), jnp.bfloat16
        )
    return batch


def decode_specs(cfg: ModelConfig, shape: str):
    """(token, pos, cache) ShapeDtypeStructs for serve_step."""
    from repro.models import model as M

    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    cfg = effective_pattern(cfg, shape)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, pos, cache
