"""The paper's SemCom model (§III-A, §V-E): a CNN autoencoder in raw JAX.

Architecture (paper §V-E): encoder = conv5x5 -> [tanh, conv] -> maxpool2x2 ->
[tanh, conv] -> tanh; decoder mirrors it (upsample + conv). AWGN is injected
between encoder and decoder during training (the "channel") so the codec is
robust to the physical link. The compression rate rho controls the bottleneck:
latent channels = ceil(rho * base_latent); for rho <= 0.5 an extra 2x2
pooling stage halves the spatial dims as in the paper.

Loss = MSE of reconstruction (the paper's FL objective). PSNR and a
[0,1]-bounded proxy accuracy are exposed so the A(rho) curve can be re-fit
from our own FL-trained models (DESIGN.md §8).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AEConfig(NamedTuple):
    image_size: int = 32
    channels: int = 3
    hidden: int = 16
    base_latent: int = 8          # latent channels at rho = 1
    rho: float = 1.0
    noise_std: float = 0.1        # AWGN channel sigma

    @property
    def latent_channels(self) -> int:
        return max(1, math.ceil(self.rho * self.base_latent))

    @property
    def extra_pool(self) -> bool:
        return self.rho <= 0.5    # paper: one more maxpool for rho <= 0.5

    @property
    def compressed_bits(self) -> float:
        """Size of the transmitted latent (float32 bits) — the C_{n,l} proxy."""
        s = self.image_size // (4 if self.extra_pool else 2)
        return float(s * s * self.latent_channels * 32)


def _conv_init(key, k, cin, cout):
    scale = 1.0 / math.sqrt(k * k * cin)
    w = jax.random.uniform(key, (k, k, cin, cout), minval=-scale, maxval=scale)
    return {"w": w, "b": jnp.zeros((cout,))}


def init_params(key: jax.Array, cfg: AEConfig):
    ks = jax.random.split(key, 6)
    lat = cfg.latent_channels
    return {
        "enc1": _conv_init(ks[0], 5, cfg.channels, cfg.hidden),
        "enc2": _conv_init(ks[1], 3, cfg.hidden, cfg.hidden),
        "enc3": _conv_init(ks[2], 3, cfg.hidden, lat),
        "dec1": _conv_init(ks[3], 3, lat, cfg.hidden),
        "dec2": _conv_init(ks[4], 3, cfg.hidden, cfg.hidden),
        "dec3": _conv_init(ks[5], 5, cfg.hidden, cfg.channels),
    }


def _conv(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _upsample(x):
    b, h, w, c = x.shape
    return jax.image.resize(x, (b, 2 * h, 2 * w, c), "nearest")


def encode(params, cfg: AEConfig, x):
    h = jnp.tanh(_conv(x, params["enc1"]))
    h = _pool(jnp.tanh(_conv(h, params["enc2"])))
    if cfg.extra_pool:
        h = _pool(h)
    return jnp.tanh(_conv(h, params["enc3"]))


def decode(params, cfg: AEConfig, z):
    h = jnp.tanh(_conv(z, params["dec1"]))
    if cfg.extra_pool:
        h = _upsample(h)
    h = _upsample(jnp.tanh(_conv(h, params["dec2"])))
    return jnp.tanh(_conv(h, params["dec3"]))


def forward(params, cfg: AEConfig, x, key=None):
    """Full codec pass; AWGN channel applied when a key is given (training)."""
    z = encode(params, cfg, x)
    if key is not None:
        z = z + cfg.noise_std * jax.random.normal(key, z.shape)
    return decode(params, cfg, z)


def mse_loss(params, cfg: AEConfig, x, key=None):
    return jnp.mean(jnp.square(forward(params, cfg, x, key) - x))


def psnr(params, cfg: AEConfig, x, key=None, peak: float = 2.0):
    m = mse_loss(params, cfg, x, key)
    return 10.0 * jnp.log10(peak**2 / jnp.maximum(m, 1e-12))


def proxy_accuracy(params, cfg: AEConfig, x, key=None,
                   lo: float = 8.0, hi: float = 28.0):
    """Map PSNR to a [0,1] 'detection-accuracy' proxy (monotone, saturating).

    Used only to re-fit A(rho); the paper's own YOLO-based fit is the default
    accuracy model for the allocator (DESIGN.md §8).
    """
    p = psnr(params, cfg, x, key)
    return jnp.clip((p - lo) / (hi - lo), 0.0, 1.0)


def param_bits(params) -> float:
    """Upload size D_n in bits (float32) — feeds the allocator."""
    return float(
        sum(x.size for x in jax.tree_util.tree_leaves(params)) * 32
    )
