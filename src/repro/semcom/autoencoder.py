"""The paper's SemCom model (§III-A, §V-E): a CNN autoencoder in raw JAX.

Architecture (paper §V-E): encoder = conv5x5 -> [tanh, conv] -> maxpool2x2 ->
[tanh, conv] -> tanh; decoder mirrors it (upsample + conv). AWGN is injected
between encoder and decoder during training (the "channel") so the codec is
robust to the physical link. The compression rate rho controls the bottleneck:
latent channels = ceil(rho * base_latent); for rho <= 0.5 an extra 2x2
pooling stage halves the spatial dims as in the paper.

Loss = MSE of reconstruction (the paper's FL objective). PSNR and a
[0,1]-bounded proxy accuracy are exposed so the A(rho) curve can be re-fit
from our own FL-trained models (DESIGN.md §8).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bits import tree_bits


class AEConfig(NamedTuple):
    image_size: int = 32
    channels: int = 3
    hidden: int = 16
    base_latent: int = 8          # latent channels at rho = 1
    rho: float = 1.0
    noise_std: float = 0.1        # AWGN channel sigma

    @property
    def latent_channels(self) -> int:
        return max(1, math.ceil(self.rho * self.base_latent))

    @property
    def extra_pool(self) -> bool:
        return self.rho <= 0.5    # paper: one more maxpool for rho <= 0.5

    @property
    def compressed_bits(self) -> float:
        """Size of the transmitted latent (float32 bits) — the C_{n,l} proxy."""
        s = self.image_size // (4 if self.extra_pool else 2)
        return float(s * s * self.latent_channels * 32)


def _conv_init(key, k, cin, cout):
    scale = 1.0 / math.sqrt(k * k * cin)
    w = jax.random.uniform(key, (k, k, cin, cout), minval=-scale, maxval=scale)
    return {"w": w, "b": jnp.zeros((cout,))}


def init_params(key: jax.Array, cfg: AEConfig):
    ks = jax.random.split(key, 6)
    lat = cfg.latent_channels
    return {
        "enc1": _conv_init(ks[0], 5, cfg.channels, cfg.hidden),
        "enc2": _conv_init(ks[1], 3, cfg.hidden, cfg.hidden),
        "enc3": _conv_init(ks[2], 3, cfg.hidden, lat),
        "dec1": _conv_init(ks[3], 3, lat, cfg.hidden),
        "dec2": _conv_init(ks[4], 3, cfg.hidden, cfg.hidden),
        "dec3": _conv_init(ks[5], 5, cfg.hidden, cfg.channels),
    }


def _conv(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _upsample(x):
    b, h, w, c = x.shape
    return jax.image.resize(x, (b, 2 * h, 2 * w, c), "nearest")


def encode(params, cfg: AEConfig, x):
    h = jnp.tanh(_conv(x, params["enc1"]))
    h = _pool(jnp.tanh(_conv(h, params["enc2"])))
    if cfg.extra_pool:
        h = _pool(h)
    return jnp.tanh(_conv(h, params["enc3"]))


def decode(params, cfg: AEConfig, z):
    h = jnp.tanh(_conv(z, params["dec1"]))
    if cfg.extra_pool:
        h = _upsample(h)
    h = _upsample(jnp.tanh(_conv(h, params["dec2"])))
    return jnp.tanh(_conv(h, params["dec3"]))


def forward(params, cfg: AEConfig, x, key=None):
    """Full codec pass; AWGN channel applied when a key is given (training)."""
    z = encode(params, cfg, x)
    if key is not None:
        z = z + cfg.noise_std * jax.random.normal(key, z.shape)
    return decode(params, cfg, z)


def mse_loss(params, cfg: AEConfig, x, key=None):
    return jnp.mean(jnp.square(forward(params, cfg, x, key) - x))


def psnr(params, cfg: AEConfig, x, key=None, peak: float = 2.0):
    m = mse_loss(params, cfg, x, key)
    return 10.0 * jnp.log10(peak**2 / jnp.maximum(m, 1e-12))


def proxy_accuracy(params, cfg: AEConfig, x, key=None,
                   lo: float = 8.0, hi: float = 28.0):
    """Map PSNR to a [0,1] 'detection-accuracy' proxy (monotone, saturating).

    Used only to re-fit A(rho); the paper's own YOLO-based fit is the default
    accuracy model for the allocator (DESIGN.md §8).
    """
    p = psnr(params, cfg, x, key)
    return jnp.clip((p - lo) / (hi - lo), 0.0, 1.0)


def param_bits(params) -> float:
    """Upload size D_n in bits (float32) — feeds the allocator."""
    return tree_bits(params)


# -- runtime-rho codec --------------------------------------------------------
#
# `AEConfig.rho` bakes the bottleneck into the parameter SHAPES (enc3/dec1 are
# built with `latent_channels` filters), so a per-round solved rho would force
# a parameter reshape mid-FL-run. The `_rho` family below keeps the parameters
# at the rho = 1 shape (`base_latent` channels) and applies rho at RUNTIME: a
# channel mask zeroes all but the first ceil(rho * base_latent) latent
# channels, and the paper's extra 2x2 pooling stage for rho <= 0.5 stays a
# static python branch (`extra_pool`) because it changes intermediate shapes.
# `repro.fl.semcom_job` selects the branch with `jax.lax.cond` per round.


def latent_mask(cfg: AEConfig, rho) -> jax.Array:
    """(base_latent,) 0/1 mask keeping ceil(rho * base_latent) channels
    (at least one). ``rho`` may be traced — the mask is where the solved
    compression rate enters the codec without touching parameter shapes."""
    keep = jnp.clip(
        jnp.ceil(jnp.asarray(rho, jnp.float32) * cfg.base_latent),
        1.0,
        float(cfg.base_latent),
    )
    return (jnp.arange(cfg.base_latent) < keep).astype(jnp.float32)


def encode_rho(params, cfg: AEConfig, x, rho, extra_pool: bool):
    """`encode` with a runtime rho: params must be the rho = 1 shape
    (``AEConfig(rho=1)`` / `base_latent` channels); ``extra_pool`` is the
    static pooling-depth branch (True for rho <= 0.5)."""
    h = jnp.tanh(_conv(x, params["enc1"]))
    h = _pool(jnp.tanh(_conv(h, params["enc2"])))
    if extra_pool:
        h = _pool(h)
    return jnp.tanh(_conv(h, params["enc3"])) * latent_mask(cfg, rho)


def decode_rho(params, cfg: AEConfig, z, extra_pool: bool):
    h = jnp.tanh(_conv(z, params["dec1"]))
    if extra_pool:
        h = _upsample(h)
    h = _upsample(jnp.tanh(_conv(h, params["dec2"])))
    return jnp.tanh(_conv(h, params["dec3"]))


def forward_rho(params, cfg: AEConfig, x, rho, key=None,
                extra_pool: bool | None = None):
    """Full codec pass at a runtime compression rate.

    ``extra_pool`` defaults from a concrete ``rho`` (<= 0.5, matching
    `AEConfig.extra_pool`); pass it explicitly when ``rho`` is traced.
    """
    if extra_pool is None:
        extra_pool = float(rho) <= 0.5
    z = encode_rho(params, cfg, x, rho, extra_pool)
    if key is not None:
        z = z + cfg.noise_std * jax.random.normal(key, z.shape)
    return decode_rho(params, cfg, z, extra_pool)


def mse_loss_rho(params, cfg: AEConfig, x, rho, key=None,
                 extra_pool: bool | None = None):
    return jnp.mean(
        jnp.square(forward_rho(params, cfg, x, rho, key, extra_pool) - x)
    )


def proxy_accuracy_rho(params, cfg: AEConfig, x, rho, key=None,
                       extra_pool: bool | None = None,
                       lo: float = 8.0, hi: float = 28.0,
                       peak: float = 2.0):
    """`proxy_accuracy` evaluated through the runtime-rho codec — the per-round
    A(rho) measurement a `SemComJob` accumulates for the refit."""
    m = mse_loss_rho(params, cfg, x, rho, key, extra_pool)
    p = 10.0 * jnp.log10(peak**2 / jnp.maximum(m, 1e-12))
    return jnp.clip((p - lo) / (hi - lo), 0.0, 1.0)


def compressed_bits_rho(cfg: AEConfig, rho: float) -> float:
    """Transmitted-latent bits at a runtime rho under the masked bottleneck.

    Agrees with ``AEConfig(rho=r).compressed_bits`` for every r: the mask
    keeps ceil(rho * base_latent) channels and rho <= 0.5 adds the pooling
    stage, exactly as the shape-baked config would.
    """
    s = cfg.image_size // (4 if rho <= 0.5 else 2)
    lat = max(1, min(cfg.base_latent, math.ceil(rho * cfg.base_latent)))
    return float(s * s * lat * 32)
