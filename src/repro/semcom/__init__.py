"""repro.semcom: the paper's CNN autoencoder, shape-static (`AEConfig.rho`)
and runtime-rho (`forward_rho` family) codecs."""
from .autoencoder import (
    AEConfig, compressed_bits_rho, decode, decode_rho, encode, encode_rho,
    forward, forward_rho, init_params, latent_mask, mse_loss, mse_loss_rho,
    param_bits, proxy_accuracy, proxy_accuracy_rho, psnr,
)

__all__ = [
    "AEConfig", "init_params", "param_bits", "latent_mask",
    "encode", "decode", "forward", "mse_loss", "psnr", "proxy_accuracy",
    "encode_rho", "decode_rho", "forward_rho", "mse_loss_rho",
    "proxy_accuracy_rho", "compressed_bits_rho",
]
