"""repro.semcom"""
