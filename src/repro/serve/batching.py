"""Admission queue + micro-batching policy for the allocation service.

Requests are grouped into per-bucket FIFO queues (a bucket key pins both the
padded (N, K) shape and the scenario meta, so everything in one queue can
stack into a single `solve_batch` call). A bucket is flushed when it is
*full* (``max_batch`` requests waiting) or *due* (its oldest request has
waited ``max_wait_s``). The batcher is sans-IO: it never reads a clock, the
caller passes ``now`` — which makes the policy exactly testable and lets the
load generator drive it on a virtual clock.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import NamedTuple

from repro.core import SystemParams, Weights


class BatchPolicy(NamedTuple):
    """Flush when a bucket holds ``max_batch`` requests or the oldest one has
    waited ``max_wait_s`` seconds — the classic latency/occupancy trade."""

    max_batch: int = 8
    max_wait_s: float = 0.05


@dataclasses.dataclass
class PendingRequest:
    """One admitted scenario waiting in a bucket queue."""

    req_id: int
    params: SystemParams        # exact shape, as submitted
    padded: SystemParams        # padded into the bucket (masks set)
    weights: Weights
    arrival_t: float
    #: the A(rho) fit this request solves AND scores under, resolved at
    #: `prepare` (explicit arg > tenant registry > service default) — rides
    #: the batch as one row of the stacked runtime accuracy argument, so
    #: co-batched tenants with different beliefs never see each other's
    #: model. None only for hand-built requests; the service always stamps it
    accuracy: object | None = None
    #: exact-shape warm-start candidate(s) attached at `prepare` (a
    #: `repro.serve.warmstart.CacheEntry`, or a tuple of them for top-k
    #: lookups — cache hit or explicit caller injection); None = cold request
    warm_start: object | None = None
    #: the request's warm-cache signature (computed once at `prepare`, reused
    #: to record the hardened solution after the flush); None when the
    #: service runs without a cache
    warm_sig: tuple | None = None


class MicroBatcher:
    """Per-bucket FIFO queues with the max-batch / max-wait flush policy.

    Guarantees: requests in one bucket are answered in submission order
    (`pop` is FIFO and caps at ``max_batch``); a request only ever co-batches
    with requests whose bucket key — padded shape AND scenario meta — is
    identical, so batching cannot change any request's compiled program or
    its answer (the `AllocService` equivalence contract). Time never comes
    from a clock here: ``now`` is caller-supplied, so the real-clock driver
    and the virtual-clock load generator exercise byte-identical policy.
    """

    def __init__(self, policy: BatchPolicy):
        self.policy = policy
        self._queues: dict[tuple, deque[PendingRequest]] = {}

    def add(self, key: tuple, req: PendingRequest) -> None:
        self._queues.setdefault(key, deque()).append(req)

    def depth(self) -> int:
        """Total requests waiting across all buckets."""
        return sum(len(q) for q in self._queues.values())

    def keys(self) -> list[tuple]:
        return [k for k, q in self._queues.items() if q]

    def deadline(self, key: tuple) -> float:
        """Virtual time at which this bucket becomes due (oldest + max_wait)."""
        return self._queues[key][0].arrival_t + self.policy.max_wait_s

    def next_deadline(self) -> float | None:
        """Earliest due-time across non-empty buckets (None when idle)."""
        deadlines = [self.deadline(k) for k in self.keys()]
        return min(deadlines) if deadlines else None

    def full_keys(self) -> list[tuple]:
        return [
            k for k, q in self._queues.items() if len(q) >= self.policy.max_batch
        ]

    def due_keys(self, now: float) -> list[tuple]:
        """Buckets that must flush at ``now``: full, or oldest waited out."""
        return [
            k
            for k, q in self._queues.items()
            if q and (len(q) >= self.policy.max_batch or now >= self.deadline(k))
        ]

    def pop(self, key: tuple) -> list[PendingRequest]:
        """Dequeue up to ``max_batch`` requests from one bucket, FIFO."""
        q = self._queues[key]
        out = [q.popleft() for _ in range(min(len(q), self.policy.max_batch))]
        return out
