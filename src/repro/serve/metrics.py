"""Serving metrics: latency percentiles, queue depth, batch occupancy, cache.

Plain-python accumulators (the service's control plane is host-side; only the
solves run on device), so they are cheap to sample on every submit/flush and
trivially serialisable into benchmark JSON.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def percentile(values, q: float) -> float:
    """q-th percentile (0..100, linear interpolation); nan on empty."""
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


@dataclasses.dataclass
class ServiceMetrics:
    """Per-service counters and reservoirs (one instance per `AllocService`)."""

    latencies_s: list = dataclasses.field(default_factory=list)   # arrival -> done
    waits_s: list = dataclasses.field(default_factory=list)       # arrival -> flush
    solves_s: list = dataclasses.field(default_factory=list)      # per batch
    queue_depth: list = dataclasses.field(default_factory=list)   # sampled on submit
    occupancy: list = dataclasses.field(default_factory=list)     # real / slots
    submitted: int = 0
    completed: int = 0
    batches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    compile_s: float = 0.0

    def observe_submit(self, depth: int) -> None:
        self.submitted += 1
        self.queue_depth.append(depth)

    def observe_batch(self, n_real: int, slots: int, solve_s: float) -> None:
        self.batches += 1
        self.occupancy.append(n_real / max(slots, 1))
        self.solves_s.append(solve_s)

    def observe_completion(self, latency_s: float, wait_s: float) -> None:
        self.completed += 1
        self.latencies_s.append(latency_s)
        self.waits_s.append(wait_s)

    def observe_cache(self, hit: bool, compile_s: float = 0.0) -> None:
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            self.compile_s += compile_s

    def summary(self) -> dict:
        mean = lambda xs: float(sum(xs) / len(xs)) if xs else float("nan")
        return {
            "requests": self.submitted,
            "completed": self.completed,
            "batches": self.batches,
            "latency_p50_s": percentile(self.latencies_s, 50.0),
            "latency_p95_s": percentile(self.latencies_s, 95.0),
            "latency_mean_s": mean(self.latencies_s),
            "wait_p50_s": percentile(self.waits_s, 50.0),
            "solve_mean_s": mean(self.solves_s),
            "queue_depth_max": max(self.queue_depth, default=0),
            "queue_depth_mean": mean(self.queue_depth),
            "batch_occupancy_mean": mean(self.occupancy),
            "mean_batch_size": self.completed / max(self.batches, 1),
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "compile_s": self.compile_s,
        }
