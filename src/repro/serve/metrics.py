"""Serving metrics: latency percentiles, queue depth, batch occupancy, cache.

Plain-python accumulators (the service's control plane is host-side; only the
solves run on device), so they are cheap to sample on every submit/flush and
trivially serialisable into benchmark JSON.

Every distribution metric lives in a bounded `Reservoir`: an indefinitely
running driver (`repro.serve.driver`) must not grow per-request lists without
bound. Below the cap the reservoir holds every observation, so percentiles
are exact; above it, it keeps a uniform random sample (Vitter's Algorithm R,
deterministically seeded) and percentiles become sample estimates — while
count / mean / max stay exact running aggregates regardless of volume.
"""
from __future__ import annotations

import dataclasses
import random

import numpy as np

#: default per-metric sample cap: exact percentiles up to this many
#: observations, ~32 KiB of floats per metric forever after
RESERVOIR_CAP = 4096


def percentile(values, q: float) -> float:
    """q-th percentile (0..100, linear interpolation); nan on empty."""
    if isinstance(values, Reservoir):
        values = values.sample
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


class Reservoir:
    """Bounded stream accumulator: exact below ``cap``, sampled above.

    ``add`` keeps every value until ``cap`` observations, then switches to
    Algorithm-R uniform reservoir sampling, so `percentile` is exact for
    short runs (every test and smoke benchmark) and an unbiased estimate for
    unbounded ones. ``count``/``total``(-> `mean`)/`max` are exact running
    aggregates either way. The RNG is seeded per-reservoir, so summaries are
    reproducible run-to-run.
    """

    __slots__ = ("cap", "count", "total", "_max", "_sample", "_rng")

    def __init__(self, cap: int = RESERVOIR_CAP, seed: int = 0):
        if cap < 1:
            raise ValueError(f"Reservoir cap must be >= 1, got {cap}")
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self._max = None
        self._sample: list[float] = []
        self._rng = random.Random(seed)

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if self._max is None or x > self._max:
            self._max = x
        if len(self._sample) < self.cap:
            self._sample.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self._sample[j] = x

    def __len__(self) -> int:
        """Observations seen (not the retained-sample size — see `sample`)."""
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    @property
    def sample(self) -> list[float]:
        """The retained values (everything below the cap, a uniform sample
        above it); at most ``cap`` long by construction."""
        return self._sample

    def mean(self) -> float:
        """Exact running mean; nan on empty."""
        return self.total / self.count if self.count else float("nan")

    def max(self, default: float = 0.0) -> float:
        """Exact running max; ``default`` on empty."""
        return self._max if self._max is not None else default

    def percentile(self, q: float) -> float:
        """q-th percentile of the retained sample (exact below the cap)."""
        return percentile(self._sample, q)


@dataclasses.dataclass
class ServiceMetrics:
    """Per-service counters and reservoirs (one instance per `AllocService`)."""

    latencies_s: Reservoir = dataclasses.field(default_factory=Reservoir)  # arrival -> done
    waits_s: Reservoir = dataclasses.field(default_factory=Reservoir)      # arrival -> flush
    solves_s: Reservoir = dataclasses.field(default_factory=Reservoir)     # per batch
    queue_depth: Reservoir = dataclasses.field(default_factory=Reservoir)  # sampled on submit
    occupancy: Reservoir = dataclasses.field(default_factory=Reservoir)    # real / slots
    #: outer iterations Alg. A2 needed to converge, split by whether the
    #: request rode a warm start (`warmstart.iters_to_converge`) — the
    #: solve-iteration-savings evidence `bench_serve` reports
    warm_iters: Reservoir = dataclasses.field(default_factory=Reservoir)
    cold_iters: Reservoir = dataclasses.field(default_factory=Reservoir)
    submitted: int = 0
    completed: int = 0
    batches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    compile_s: float = 0.0
    warm_hits: int = 0
    warm_misses: int = 0

    def observe_submit(self, depth: int) -> None:
        self.submitted += 1
        self.queue_depth.add(depth)

    def observe_batch(self, n_real: int, slots: int, solve_s: float) -> None:
        self.batches += 1
        self.occupancy.add(n_real / max(slots, 1))
        self.solves_s.add(solve_s)

    def observe_completion(self, latency_s: float, wait_s: float) -> None:
        self.completed += 1
        self.latencies_s.add(latency_s)
        self.waits_s.add(wait_s)

    def observe_warm(self, hit: bool, iters: int) -> None:
        """Record one completed request's convergence iterations under the
        warm/cold split (only called when the service has warm starts in
        play, so a cold-only service's summary stays unchanged)."""
        if hit:
            self.warm_hits += 1
            self.warm_iters.add(iters)
        else:
            self.warm_misses += 1
            self.cold_iters.add(iters)

    def observe_cache(self, hit: bool, compile_s: float = 0.0) -> None:
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            self.compile_s += compile_s

    def summary(self) -> dict:
        return {
            "requests": self.submitted,
            "completed": self.completed,
            "batches": self.batches,
            "latency_p50_s": self.latencies_s.percentile(50.0),
            "latency_p95_s": self.latencies_s.percentile(95.0),
            "latency_mean_s": self.latencies_s.mean(),
            "wait_p50_s": self.waits_s.percentile(50.0),
            "solve_mean_s": self.solves_s.mean(),
            "queue_depth_max": int(self.queue_depth.max(default=0)),
            "queue_depth_mean": self.queue_depth.mean(),
            "batch_occupancy_mean": self.occupancy.mean(),
            "mean_batch_size": self.completed / max(self.batches, 1),
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "compile_s": self.compile_s,
            "warm_hits": self.warm_hits,
            "warm_misses": self.warm_misses,
            "warm_iters_mean": self.warm_iters.mean(),
            "cold_iters_mean": self.cold_iters.mean(),
        }
