"""Learned bucket ladders: fit `ShapeBucket` sets to an observed shape mix.

`DEFAULT_BUCKETS` is a hand-picked geometric grid; a real deployment sees a
*specific* (N, K) distribution drawn from its device fleet, and every padded
slot it never needed is wasted solve time (cost scales with the padded area
N_pad x K_pad, not the real one). This module learns a replacement ladder
from a shape histogram:

    minimise   E_{(n,k) ~ mix}[ area(bucket_for(n, k, L)) - n*k ]
    over       ladders L with |L| <= max_buckets covering every shape

i.e. expected padded-area waste under EXACTLY the assignment rule the
service uses (`bucket_for`: smallest-area covering bucket). Fewer buckets is
also better on a second axis — each bucket is one AOT-compiled executable in
the `AllocService` cache — which is why ``max_buckets`` is a hard budget.

The optimiser is greedy set-augmentation over the finite candidate grid
``{(n_i, k_j)}`` of observed shape coordinates (an optimal ladder only needs
those: shrinking any bucket to the componentwise max of the shapes it serves
never increases waste and never breaks coverage):

1. seed with the must-have cover bucket ``(max n, max k)``;
2. repeatedly add the candidate that most reduces expected waste;
3. stop at ``max_buckets`` or when no candidate strictly improves.

Each step re-scores the full histogram exactly, so the result is monotone in
the budget and exact whenever one bucket per distinct shape fits the budget
(waste 0 on the observed mix). `LadderLearner` wraps this in a thread-safe
accumulator with the ``refit`` hook the real-clock driver calls between
epochs (`AllocService.set_buckets` makes the swap safe mid-stream).
"""
from __future__ import annotations

import threading
from collections import Counter
from typing import Iterable, Mapping, NamedTuple

from repro.core.types import DEFAULT_BUCKETS, ShapeBucket, bucket_for

#: matches DEFAULT_BUCKETS' cache footprint: a learned ladder should beat the
#: default on waste without holding more compiled executables
DEFAULT_MAX_BUCKETS = len(DEFAULT_BUCKETS)


def _as_counts(shapes) -> Counter:
    """Normalise ``shapes`` — an iterable of (n, k) or a {(n, k): count}
    mapping — into a validated Counter."""
    counts = Counter(dict(shapes.items()) if isinstance(shapes, Mapping) else list(shapes))
    if not counts:
        raise ValueError("need at least one observed (n, k) shape")
    for (n, k), c in counts.items():
        if c <= 0:
            raise ValueError(f"shape ({n}, {k}) has non-positive count {c}")
        if n < 1 or k < n:
            raise ValueError(
                f"observed shape (N={n}, K={k}) violates K >= N >= 1 "
                "(the SystemParams contract)"
            )
    return counts


def padded_area_waste(shapes, buckets: Iterable[ShapeBucket]) -> float:
    """Expected *relative* padded-area waste of a ladder on a shape mix:
    ``E[area(bucket) - n*k] / E[n*k]`` under `bucket_for` assignment
    (0 = every shape lands in an exactly-fitting bucket).

    Raises (via `bucket_for`) if some observed shape fits no bucket, so a
    candidate ladder is validated and scored in one call.
    """
    counts = _as_counts(shapes)
    buckets = tuple(buckets)
    pad_area = real_area = 0.0
    for (n, k), c in counts.items():
        pad_area += c * bucket_for(n, k, buckets).area
        real_area += c * n * k
    return pad_area / real_area - 1.0


def learn_buckets(
    shapes,
    max_buckets: int = DEFAULT_MAX_BUCKETS,
    must_fit: Iterable[tuple[int, int]] = (),
) -> tuple[ShapeBucket, ...]:
    """Greedy expected-waste-minimising ladder for a shape mix (see module
    docstring). ``shapes`` is an iterable of (n, k) or a {(n, k): count}
    histogram; ``must_fit`` optionally adds zero-count shapes the ladder must
    cover anyway (e.g. a size the operator knows is coming). Returns buckets
    sorted ascending by (area, N) — a drop-in for ``ServeConfig.buckets``.
    """
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    counts = _as_counts(shapes)
    cover = Counter(counts)
    for n, k in must_fit:
        if n < 1 or k < n:            # same contract as observed shapes
            raise ValueError(
                f"must_fit shape (N={n}, K={k}) violates K >= N >= 1"
            )
        cover.setdefault((n, k), 0)   # coverage constraint, no waste weight

    ns = sorted({n for n, _ in cover})
    ks = sorted({k for _, k in cover})
    # candidate buckets: the observed coordinate grid (k >= n is the
    # ShapeBucket contract; a candidate violating it covers no valid shape
    # that a (n', k') with k' >= n' wouldn't cover at <= area)
    candidates = {ShapeBucket(n, k) for n in ns for k in ks if k >= n}
    seed = ShapeBucket(max(ns), max(ks))   # covers everything (max k >= max n)
    chosen = {seed}
    candidates.discard(seed)

    # incremental greedy: track each weighted shape's current padded area
    # under `chosen` (assignment = smallest covering area, i.e. `bucket_for`
    # minus its waste-irrelevant N tie-break). Adding candidate c re-assigns
    # exactly the shapes it fits with a smaller area, so its waste reduction
    # is sum(count * (cur_area - c.area)) over those — O(|shapes|) per
    # candidate instead of re-scoring the whole histogram through bucket_for
    # (fleet-sized mixes make the naive rescore minutes per refit).
    weighted = [(n, k, c) for (n, k), c in counts.items() if c]
    cur = {(n, k): seed.area for n, k, _ in weighted}

    def gain(cand: ShapeBucket) -> float:
        g = 0.0
        for n, k, c in weighted:
            if cand.fits(n, k) and cand.area < cur[(n, k)]:
                g += c * (cur[(n, k)] - cand.area)
        return g

    best = sum(c * (seed.area - n * k) for n, k, c in weighted)
    while len(chosen) < max_buckets and candidates and best > 0.0:
        pick, picked_gain = None, 0.0
        # deterministic scan order (sets hash-shuffle): equal-gain ties go to
        # the smallest-area candidate, so refits are reproducible run-to-run
        for cand in sorted(candidates, key=lambda b: (b.area, b.N)):
            g = gain(cand)
            if g > picked_gain:
                pick, picked_gain = cand, g
        if pick is None:
            break                      # no candidate strictly improves
        chosen.add(pick)
        candidates.discard(pick)
        best -= picked_gain
        for n, k, _ in weighted:
            if pick.fits(n, k) and pick.area < cur[(n, k)]:
                cur[(n, k)] = pick.area
    return tuple(sorted(chosen, key=lambda b: (b.area, b.N)))


class LadderSnapshot(NamedTuple):
    """One `LadderLearner.refit` result, with its predicted waste."""

    buckets: tuple[ShapeBucket, ...]
    waste: float               # padded_area_waste of `buckets` on the mix
    baseline_waste: float      # same mix under the learner's fallback ladder
    n_observed: int


class LadderLearner:
    """Accumulates the observed (N, K) mix and refits a bucket ladder on
    demand — the autoscaling half of the serving front-end.

    ``observe`` is thread-safe (the driver calls it from caller threads on
    every admission); ``refit`` greedily re-learns a ladder from the counts
    so far and returns a `LadderSnapshot`, falling back to ``fallback``
    (default `DEFAULT_BUCKETS`) until ``min_samples`` shapes have been seen.
    The learned ladder always additionally covers every ``fallback`` shape
    region's observed shapes by construction (it is fit on observations), but
    NOT unseen future shapes — pass ``must_fit`` shapes to `refit` or keep a
    headroom bucket in mind if the mix can grow.
    """

    def __init__(
        self,
        max_buckets: int = DEFAULT_MAX_BUCKETS,
        min_samples: int = 1,
        fallback: tuple[ShapeBucket, ...] = DEFAULT_BUCKETS,
    ):
        self.max_buckets = max_buckets
        self.min_samples = min_samples
        self.fallback = tuple(fallback)
        self._counts: Counter = Counter()
        self._lock = threading.Lock()

    def observe(self, n: int, k: int, count: int = 1) -> None:
        """Record ``count`` arrivals of an exact (n, k) scenario shape."""
        if count <= 0:
            # a zero/negative entry would poison the histogram and make a
            # later refit() raise from _as_counts instead of returning
            raise ValueError(f"observe count must be >= 1, got {count}")
        with self._lock:
            self._counts[(int(n), int(k))] += count

    @property
    def n_observed(self) -> int:
        with self._lock:
            return sum(self._counts.values())

    def counts(self) -> dict:
        """Snapshot of the observed {(n, k): count} histogram."""
        with self._lock:
            return dict(self._counts)

    def refit(self, must_fit: Iterable[tuple[int, int]] = ()) -> LadderSnapshot:
        """Learn a fresh ladder from everything observed so far."""
        counts = self.counts()
        n_obs = sum(counts.values())
        # the fallback ladder may not cover every observed shape (that can be
        # exactly why a learner is in play) — score it as inf, don't crash
        base_waste = self._waste_or_inf(counts, self.fallback)
        if n_obs < self.min_samples:
            return LadderSnapshot(
                buckets=self.fallback,
                waste=base_waste,
                baseline_waste=base_waste,
                n_observed=n_obs,
            )
        buckets = learn_buckets(counts, self.max_buckets, must_fit=must_fit)
        return LadderSnapshot(
            buckets=buckets,
            waste=padded_area_waste(counts, buckets),
            baseline_waste=base_waste,
            n_observed=n_obs,
        )

    @staticmethod
    def _waste_or_inf(counts, buckets) -> float:
        """`padded_area_waste`, but uncoverable mixes score inf (a ladder
        that cannot serve the mix is infinitely wasteful, not an error) and
        an empty mix scores nan."""
        if not counts:
            return float("nan")
        try:
            return padded_area_waste(counts, buckets)
        except ValueError:
            return float("inf")
