"""Warm-start solution-reuse cache for recurring users.

At millions-of-users scale, serving requests are not i.i.d.: a device's
channel state is temporally correlated (the ``gauss_markov`` scenario family
is exactly that trace) and the same (N, K) populations recur, so the
allocator keeps re-deriving solutions it has already found. This module
keys a bounded, thread-safe cache on a *quantized signature* of the
request — canonical bucket meta, per-device mean channel gains, the A(rho)
accuracy fit and the objective weights — and feeds hits back into
`solve_batch` as one more multi-start candidate (`core.allocator.ExtraStart`).

Why coarse quantization is safe — the dominance invariant: the multi-start
machinery already selects the best candidate, so a cache hit can only help
or tie, never hurt (`refine_with_start`: a stale or outright wrong-scenario
entry is re-solved and re-scored under the CURRENT scenario and accuracy
model, and loses the argmin if it isn't better). That frees the signature to
be deliberately lossy — ~6 dB gain steps collide "similar enough" channels
onto one key, which is what produces hits on a correlated trace — because a
wrong collision costs one extra inner solve, not a wrong answer.

Equivalence rows this module adds (docs/ARCHITECTURE.md, gated in
tests/test_warmstart.py and `bench_serve`):

* **cold == disabled, exact X**: with the cache empty or ``warmstart=None``
  the service runs the UNCHANGED cold executable — bit-for-bit the same
  hardened assignment as today.
* **warm never-worse objective**: with any cache state, every request's
  eq. 13 objective is <= its cold objective (tie allowed, float32
  round-off).

Storage is exact-shape: entries hold the hardened (f, P, X) at the
scenario's real (N, K) and are padded into whatever bucket the *next*
request lands in at attach time (`pad_start` is mask-aware, mirroring
`pad_params`), so one cached solution serves every covering bucket and
ladder refits never invalidate the cache.
"""
from __future__ import annotations

import math
import threading
from collections import OrderedDict
from typing import NamedTuple

import numpy as np

from repro.core import SystemParams, Weights
from repro.core.allocator import ExtraStart


class WarmStartConfig(NamedTuple):
    """Warm-start cache knobs (attach to `ServeConfig.warmstart`; None there
    disables the cache entirely — the cold path, bit-for-bit)."""

    #: max cached solutions; beyond it the least-recently-USED entry is
    #: evicted (a hit refreshes recency), bounding memory like the metrics
    #: reservoirs bound theirs
    capacity: int = 256
    #: per-device mean-gain quantization step [dB]: requests whose per-device
    #: mean channel gains agree within this step share a signature. Coarse on
    #: purpose — see the module docstring's dominance argument
    gain_quant_db: float = 6.0
    #: significant figures kept of the A(rho) fit (a, b) in the signature; a
    #: re-fit within round-off hits the same key (stale entries re-score
    #: under the NEW model — the set_accuracy regression test)
    acc_digits: int = 3
    #: significant figures kept of the objective weights (kappa1..3)
    weight_digits: int = 3
    #: relative tolerance declaring the objective trace "converged" for the
    #: solve-iteration-savings metric (`iters_to_converge`)
    iters_rtol: float = 1e-3
    #: warm-start candidates fed per request: 1 (default) attaches the exact
    #: signature hit only — the legacy single-candidate program, bit-for-bit.
    #: k > 1 additionally attaches up to k-1 nearest quantized-signature
    #: NEIGHBOURS (`WarmStartCache.lookup`: same signature except the gain
    #: steps, ranked by L1 gain-step distance) and the refine pass argmins
    #: over the whole candidate list — dominance still holds per candidate
    top_k: int = 1


class CacheEntry(NamedTuple):
    """One cached solution at its scenario's EXACT (N, K) shape (numpy, host
    memory — entries never pin device buffers)."""

    f: np.ndarray   # (N,)
    P: np.ndarray   # (N, K)
    X: np.ndarray   # (N, K) hardened {0,1}
    objective: float  # eq. 13 value when recorded (diagnostic ONLY — hits
    #                   are always re-scored under the current scenario and
    #                   accuracy model, never trusted from here)


def _quant_sig(x: float, digits: int) -> float:
    """Round to ``digits`` significant figures (signature canonicalisation,
    same scheme as the service's bucket-key `_round_sig` but coarser)."""
    x = float(x)
    if x == 0.0 or not math.isfinite(x):
        return x
    return round(x, digits - 1 - math.floor(math.log10(abs(x))))


def request_signature(
    params: SystemParams,
    weights: Weights,
    acc,
    cfg: WarmStartConfig = WarmStartConfig(),
) -> tuple:
    """Hashable, deliberately-lossy identity of a request for cache keying.

    Components: exact shape (N, K) and the canonical bucket meta (per-
    subcarrier bandwidth, noise PSD, xi, eta, q) — these must match exactly
    for an entry's arrays to even be shape-compatible — plus the lossy part:
    per-device MEAN channel gain quantized to ``gain_quant_db`` steps, and
    the accuracy fit / objective weights rounded to a few significant
    figures. Correlated channels (``gauss_markov``) drift slowly through the
    quantization cells, so consecutive requests from the same population
    collide on purpose; the dominance invariant makes any false collision
    harmless (module docstring).
    """
    g = np.asarray(params.g, dtype=np.float64)
    mask = np.asarray(params.dev_mask, dtype=np.float64)
    # per-device mean gain in dB, quantized; padded devices (mask 0) read 0
    mean_g = np.maximum(g.mean(axis=-1), 1e-30)
    steps = np.rint(10.0 * np.log10(mean_g) / cfg.gain_quant_db)
    gains = tuple(int(s) if m > 0 else 0 for s, m in zip(steps, mask))
    a = _quant_sig(getattr(acc, "a", 0.0), cfg.acc_digits)
    b = _quant_sig(getattr(acc, "b", 0.0), cfg.acc_digits)
    kappas = tuple(
        _quant_sig(k, cfg.weight_digits)
        for k in (weights.kappa1, weights.kappa2, weights.kappa3)
    )
    bbar = _quant_sig(params.B / params.K, 12)
    return (
        params.N, params.K, bbar, params.N0, params.xi, params.eta, params.q,
        gains, (a, b), kappas,
    )


class WarmStartCache:
    """Bounded, thread-safe LRU of `CacheEntry` keyed by `request_signature`.

    `get` runs on CALLER threads (the driver attaches hits during `prepare`,
    off the solver thread); `put` runs on the solver thread after each flush
    — hence the lock. Both are O(1) OrderedDict moves; entries are plain
    numpy, so neither path touches the device. Stats are monotonic counters
    snapshot by `stats()` (`bench_serve` gates hit accounting on them:
    hits + misses == lookups, puts - evictions == len).
    """

    def __init__(self, cfg: WarmStartConfig = WarmStartConfig()):
        if cfg.capacity < 1:
            raise ValueError(f"warm-start capacity must be >= 1, got {cfg.capacity}")
        self.cfg = cfg
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, CacheEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, sig: tuple) -> CacheEntry | None:
        with self._lock:
            entry = self._entries.get(sig)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(sig)   # refresh LRU recency
            self.hits += 1
            return entry

    def lookup(self, sig: tuple, k: int | None = None) -> list[CacheEntry]:
        """Up to ``k`` warm-start candidates for ``sig``, best first.

        ``k`` defaults to ``cfg.top_k``. With ``k == 1`` this is exactly
        `get` (exact-signature hit or nothing — the legacy path, same LRU
        refresh and hit/miss accounting). With ``k > 1`` the exact hit (if
        any) leads and the remainder are the nearest NEIGHBOURS: entries
        whose signature matches in every component except the quantized gain
        steps, ranked by L1 distance over those steps. Neighbour reads do
        not refresh recency (they are speculative candidates, not uses of
        their own key) and the call still counts one hit/miss: a lookup is a
        hit iff it returns any candidate.
        """
        if k is None:
            k = self.cfg.top_k
        if k <= 1:
            entry = self.get(sig)
            return [entry] if entry is not None else []
        with self._lock:
            out = []
            exact = self._entries.get(sig)
            if exact is not None:
                self._entries.move_to_end(sig)
                out.append(exact)
            ref_gains = sig[7]
            scored = []
            for other, entry in self._entries.items():
                if other == sig or other[:7] != sig[:7] or other[8:] != sig[8:]:
                    continue
                dist = sum(abs(a - b) for a, b in zip(ref_gains, other[7]))
                scored.append((dist, other, entry))
            scored.sort(key=lambda t: (t[0], t[1]))
            out.extend(e for _, _, e in scored[: k - len(out)])
            if out:
                self.hits += 1
            else:
                self.misses += 1
            return out

    def put(self, sig: tuple, entry: CacheEntry) -> None:
        with self._lock:
            self.puts += 1
            self._entries[sig] = entry
            self._entries.move_to_end(sig)
            while len(self._entries) > self.cfg.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def stats(self) -> dict:
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "warm_cache_size": len(self._entries),
                "warm_cache_capacity": self.cfg.capacity,
                "warm_cache_hits": self.hits,
                "warm_cache_misses": self.misses,
                "warm_cache_puts": self.puts,
                "warm_cache_evictions": self.evictions,
                "warm_cache_hit_rate": self.hits / lookups if lookups else 0.0,
            }


def entry_from_alloc(alloc, objective: float | None = None) -> CacheEntry:
    """Freeze an exact-shape `Allocation` into a host-side `CacheEntry`."""
    return CacheEntry(
        f=np.asarray(alloc.f, dtype=np.float32),
        P=np.asarray(alloc.P, dtype=np.float32),
        X=np.asarray(alloc.X, dtype=np.float32),
        objective=float(objective) if objective is not None else float("nan"),
    )


def pad_start(entry: CacheEntry, padded: SystemParams) -> tuple:
    """Pad an exact-shape entry to a bucket's (N_pad, K_pad) — mask-aware,
    mirroring `pad_params`: the real block carries the cached solution, the
    padded tail gets the built-in starts' inert values (f = f_max/2, P = X =
    0), so a padded warm candidate solves exactly like its exact-shape twin
    (gated by the padded-vs-exact-hit test)."""
    n, k = entry.f.shape[0], entry.P.shape[1]
    f = 0.5 * np.asarray(padded.f_max, dtype=np.float32).copy()
    f[:n] = entry.f
    P = np.zeros((padded.N, padded.K), dtype=np.float32)
    P[:n, :k] = entry.P
    X = np.zeros((padded.N, padded.K), dtype=np.float32)
    X[:n, :k] = entry.X
    return f, P, X


def batch_starts(
    entries: list, padded_list: list, k: int | None = None
) -> ExtraStart | None:
    """Stack per-slot cache hits into the `ExtraStart` batch `solve_batch`
    consumes; ``entries[i]`` is None (miss), one `CacheEntry`, or a
    list/tuple of candidates (`WarmStartCache.lookup` top-k). Misses get
    placeholder arrays with ``valid`` 0 — the refine pass returns that row's
    cold result bit-for-bit. Returns None when every slot missed, which
    tells the service to run the PLAIN cold executable — the cold==disabled
    row.

    Shape discipline keeps the compiled-program count bounded: when every
    slot holds at most ONE candidate the legacy (B,)-valid layout is
    emitted (bit-compatible with the single-candidate refine program);
    otherwise candidates pad to a (B, C) axis with C = ``k`` when given
    (so every multi-candidate flush of a service shares one program) else
    the flush's max candidate count.
    """
    # NB: CacheEntry IS a tuple (NamedTuple) — test it first or a single
    # entry would explode into its four field arrays
    norm = [
        []
        if e is None
        else [e]
        if isinstance(e, CacheEntry)
        else list(e)
        if isinstance(e, (list, tuple))
        else [e]
        for e in entries
    ]
    c_max = max((len(c) for c in norm), default=0)
    if c_max == 0:
        return None
    if c_max <= 1:
        fs, Ps, Xs, valid = [], [], [], []
        for cands, padded in zip(norm, padded_list):
            if not cands:
                fs.append(0.5 * np.asarray(padded.f_max, dtype=np.float32))
                Ps.append(np.zeros((padded.N, padded.K), dtype=np.float32))
                Xs.append(np.zeros((padded.N, padded.K), dtype=np.float32))
                valid.append(0.0)
            else:
                f, P, X = pad_start(cands[0], padded)
                fs.append(f)
                Ps.append(P)
                Xs.append(X)
                valid.append(1.0)
        return ExtraStart(
            f=np.stack(fs),
            P=np.stack(Ps),
            X=np.stack(Xs),
            valid=np.asarray(valid, dtype=np.float32),
        )
    C = max(c_max, k or 0)
    fs, Ps, Xs, valid = [], [], [], []
    for cands, padded in zip(norm, padded_list):
        row_f, row_P, row_X, row_v = [], [], [], []
        for c in range(C):
            if c < len(cands):
                f, P, X = pad_start(cands[c], padded)
                v = 1.0
            else:
                f = 0.5 * np.asarray(padded.f_max, dtype=np.float32)
                P = np.zeros((padded.N, padded.K), dtype=np.float32)
                X = np.zeros((padded.N, padded.K), dtype=np.float32)
                v = 0.0
            row_f.append(f)
            row_P.append(P)
            row_X.append(X)
            row_v.append(v)
        fs.append(np.stack(row_f))
        Ps.append(np.stack(row_P))
        Xs.append(np.stack(row_X))
        valid.append(np.asarray(row_v, dtype=np.float32))
    return ExtraStart(
        f=np.stack(fs),
        P=np.stack(Ps),
        X=np.stack(Xs),
        valid=np.stack(valid),
    )


def iters_to_converge(trace, rtol: float = 1e-3) -> int:
    """Outer iterations Alg. A2 needed before its objective trace entered
    ``rtol`` of the final value (the solve-iteration-savings metric: a warm
    start that lands near the optimum converges in fewer outer iterations
    than a cold one, even though the compiled program always runs all of
    them). Returns the 1-based iteration count; non-finite traces count as
    the full length (never converged)."""
    t = np.asarray(trace, dtype=np.float64).ravel()
    if t.size == 0 or not np.isfinite(t[-1]):
        return int(t.size)
    tol = rtol * max(1.0, abs(float(t[-1])))
    within = np.abs(t - t[-1]) <= tol
    # first index from which the trace STAYS within tolerance
    stays = np.flip(np.logical_and.accumulate(np.flip(within)))
    first = int(np.argmax(stays)) if stays.any() else t.size - 1
    return first + 1
