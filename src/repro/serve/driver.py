"""Real-clock async serving driver over the sans-IO `AllocService`.

`AllocService` is deliberately IO-free: it owns queues, the compiled-solver
cache and flush policy, but never reads a clock or spawns a thread. This
module is the real-clock front-end the ROADMAP called for — the piece that
serves a *concurrent* request stream the way a FedSem base station would
re-solve eq. 13 online:

Thread topology (two roles, N callers + 1 solver):

    caller threads              solver thread (owns the service)
    --------------              --------------------------------
    submit():                   loop:
      service.prepare()  ──┐      wait on admission queue, with a timeout
      (pads on the host,   │      that expires at the earliest bucket
       overlapping any     │      deadline (the `flush_due` timer)
       running solve —     ├──►   admit everything queued (cheap appends)
       XLA releases the    │      service.flush_due(now)  [full OR expired]
       GIL)                │      resolve futures
      bounded queue.put() ─┘    on close(): drain queue, service.drain()

* The **admission path** runs on the caller's thread: `AllocService.prepare`
  does the host-side padding/canonicalisation work, which overlaps the
  solver thread's device solves (XLA computations release the GIL). The
  prepared request then enters a **bounded** admission queue — when the
  solver falls behind, `submit` blocks (backpressure) or raises
  `AdmissionQueueFull`, it never grows memory without bound.
* The **solver thread** is the only thread that mutates the service, so the
  virtual-clock `run_load` and this driver exercise *byte-identical* policy
  code single-threaded — the equivalence contract (same stream => same
  hardened X per request) holds by construction, not by luck
  (`tests/test_serve_driver.py` asserts it).
* The **timer** is the solver loop's queue timeout: it wakes exactly at the
  next `MicroBatcher` deadline and fires `flush_due`, so max-wait flushes
  happen on time even when no new request arrives.
* `close()` performs a graceful **drain**: admission is fenced off, whatever
  is still queued is admitted, and `service.drain` flushes every bucket
  before the thread exits — no submitted request is ever dropped.

An optional `LadderLearner` observes every admitted (N, K); `refit()` swaps
the service's bucket ladder in place between epochs (safe mid-stream, see
`AllocService.set_buckets`). With ``DriverConfig.refit_waste_threshold`` set,
the solver thread also *auto*-refits: every ``refit_check_every`` admissions
it scores the observed shape mix's padded-area waste under the service's
current ladder and refits when the mix has drifted past the threshold — a
time-correlated workload (the ``gauss_markov`` scenario stream) shifts its
shape mix mid-run, and the ladder follows without an operator hook. Swapping
ladders mid-stream cannot change answers: padding is answer-transparent
(identical hardened X through any covering bucket), so the real==virtual
equivalence gate holds across refits.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import NamedTuple

import numpy as np

from repro.core import SystemParams, Weights

from .ladder import LadderLearner, LadderSnapshot
from .service import AllocService, Completion

_SENTINEL = object()


def pace_stream(
    driver: "RealClockDriver", requests, schedule, weights=None
) -> tuple[list[Future], float]:
    """Replay a request stream against the real clock: submit ``requests[i]``
    at offset ``schedule[i]`` seconds from the call (sleeping on the caller
    thread between arrivals, i.e. this thread IS the arrival process).
    Returns (futures in submission order, the driver-clock start offset) —
    makespan is ``driver.now() - t0`` once the stream is drained. Shared by
    `repro.launch.serve_alloc --driver real` and the serving benchmark."""
    requests = list(requests)
    schedule = list(schedule)
    # fail before pacing starts, not with an IndexError (weights) or a
    # silently zip-truncated stream (schedule) mid-run
    if len(schedule) != len(requests):
        raise ValueError(
            f"schedule ({len(schedule)}) and requests ({len(requests)}) differ"
        )
    if weights is not None and len(weights) != len(requests):
        raise ValueError(
            f"weights ({len(weights)}) and requests ({len(requests)}) differ"
        )
    t0 = driver.now()
    futures = []
    for i, (params, t_arr) in enumerate(zip(requests, schedule)):
        lag = t0 + float(t_arr) - driver.now()
        if lag > 0:
            time.sleep(lag)
        futures.append(
            driver.submit(params, weights[i] if weights is not None else None)
        )
    return futures, t0


def same_hardened_assignments(a, b) -> bool:
    """THE driver equivalence predicate: two completion streams answered the
    same requests with identical hardened assignments (req_id -> exact X).

    This is what "the real-clock driver == the virtual-clock loadgen" means
    everywhere it is gated (`tests/test_serve_driver.py`, the `bench_serve`
    check, `serve_alloc --driver real --smoke`): completion ORDER may differ
    (real timing moves batch boundaries), the answers may not.
    """
    xa = {c.req_id: np.asarray(c.alloc.X) for c in a}
    xb = {c.req_id: np.asarray(c.alloc.X) for c in b}
    return sorted(xa) == sorted(xb) and all(
        np.array_equal(xa[i], xb[i]) for i in xa
    )


class AdmissionQueueFull(RuntimeError):
    """The bounded admission queue is full and the driver was configured (or
    timed out) not to wait — the caller should shed or retry (backpressure)."""


class DriverClosed(RuntimeError):
    """submit() after close(): the driver is draining or drained."""


class DriverConfig(NamedTuple):
    """Real-clock driver knobs (the batching policy itself lives in
    `ServeConfig` — this only shapes the IO front-end)."""

    #: admission-queue bound: max prepared requests waiting for the solver
    #: thread; the backpressure surface
    queue_capacity: int = 256
    #: True: submit() blocks while the queue is full (up to
    #: ``submit_timeout_s``); False: a full queue raises immediately
    block: bool = True
    #: max seconds submit() may block on a full queue (None = forever);
    #: expiry raises `AdmissionQueueFull`
    submit_timeout_s: float | None = None
    #: solver-thread wake-up interval while fully idle (no pending requests,
    #: nothing queued); bounds close() latency, not correctness
    idle_poll_s: float = 0.05
    #: how many recent Completions ``driver.completions`` retains (None =
    #: unbounded). Bounded by default for the same reason the metrics
    #: reservoirs are: an indefinitely running driver must not grow
    #: per-request state — callers get every answer through their Future
    completion_log: int | None = 4096
    #: auto-refit trigger: when a `LadderLearner` is attached and the
    #: observed mix's relative padded-area waste under the service's CURRENT
    #: ladder exceeds this, the solver thread refits and swaps the ladder
    #: (None = manual ``driver.refit()`` only). An uncoverable shape scores
    #: the current ladder inf, so drift into unserved sizes always trips it
    refit_waste_threshold: float | None = None
    #: admissions between drift checks (amortises the waste rescore)
    refit_check_every: int = 64
    #: observations required before the first auto-refit may fire (early
    #: tiny mixes look maximally skewed; don't thrash the executable cache)
    refit_min_samples: int = 32


class RealClockDriver:
    """Threaded real-clock front-end over one `AllocService` (module doc).

    Usage::

        service = AllocService(cfg)
        service.warmup(example_stream)
        with RealClockDriver(service) as driver:
            futures = [driver.submit(p) for p in stream]   # any thread(s)
            answers = [f.result(timeout=60) for f in futures]
        # `with` exit == driver.close(): drains everything, joins the thread

    ``submit`` returns a `concurrent.futures.Future` resolving to the
    request's `Completion`. Completion order is also recorded in
    ``driver.completions``. All service timestamps are seconds on a
    monotonic clock starting ~0 at driver construction, so metric summaries
    read like the virtual-clock ones.
    """

    def __init__(
        self,
        service: AllocService,
        cfg: DriverConfig = DriverConfig(),
        ladder: LadderLearner | None = None,
        start: bool = True,
    ):
        self.service = service
        self.cfg = cfg
        self.ladder = ladder
        self._t0 = time.monotonic()
        self._inbox: queue.Queue = queue.Queue(maxsize=cfg.queue_capacity)
        self._tickets: dict[int, Future] = {}     # solver-thread only
        #: most recent completions in completion order (bounded by
        #: ``cfg.completion_log``; every completion also resolves its Future)
        self.completions: deque[Completion] = deque(maxlen=cfg.completion_log)
        #: auto-refit bookkeeping (solver-thread only): admissions seen, the
        #: admission count that triggers the next drift check, refits fired
        self._admitted = 0
        self._next_refit_check = cfg.refit_check_every
        self.auto_refits = 0
        self._closed = threading.Event()
        #: serialises the closed-check-then-enqueue in submit() against
        #: close()'s fence + post-join sweep, so an admission can never land
        #: in the inbox after the final drain (it either precedes the
        #: sentinel or raises DriverClosed)
        self._fence = threading.Lock()
        self._error: BaseException | None = None
        self._started = False
        self._thread = threading.Thread(
            target=self._run, name="alloc-driver-solver", daemon=True
        )
        if start:
            self.start()

    # -- caller-thread API ---------------------------------------------------

    def now(self) -> float:
        """Seconds since driver construction (the clock all service
        timestamps use)."""
        return time.monotonic() - self._t0

    def submit(
        self,
        params: SystemParams,
        weights: Weights | None = None,
        warm_start=None,
        accuracy=None,
        tenant=None,
    ) -> Future:
        """Admit one scenario from any thread; returns a Future resolving to
        its `Completion`.

        Pads/canonicalises on THIS thread (overlapping any running solve),
        then enqueues on the bounded admission queue: blocks under
        backpressure when ``cfg.block`` (up to ``cfg.submit_timeout_s``),
        else raises `AdmissionQueueFull`. ``warm_start`` optionally injects
        explicit warm-start entry/entries (`repro.serve.warmstart.CacheEntry`
        or a tuple of them), overriding any cache lookup — the FL backend's
        round-to-round reuse and the replay gate use this; normal serving
        leaves it None and lets the service's cache attach hits.
        ``accuracy``/``tenant`` select the A(rho) fit the request is stamped
        with at prepare (`AllocService._resolve_accuracy`): per-tenant FL
        jobs sharing this driver pass their tenant id so refits never touch
        a co-tenant's requests.
        """
        if self._closed.is_set():
            raise DriverClosed("driver is closed; no further admissions")
        prepared = self.service.prepare(params, weights, warm_start, accuracy, tenant)
        fut: Future = Future()
        # re-check + enqueue under the fence: close() flips the flag under
        # the same lock, so a submit that slept through close() during the
        # prepare() above raises here instead of enqueueing into a queue
        # nobody will ever drain again. Backpressure blocking happens inside
        # the fence too, which serialises blocked submitters — fine, they
        # were going to wait for the same solver anyway.
        with self._fence:
            if self._closed.is_set():
                raise DriverClosed("driver is closed; no further admissions")
            try:
                self._inbox.put(
                    (prepared, fut, self.now()),
                    block=self.cfg.block,
                    timeout=self.cfg.submit_timeout_s,
                )
            except queue.Full:
                raise AdmissionQueueFull(
                    f"admission queue full ({self.cfg.queue_capacity} waiting); "
                    "solver thread is behind — shed load or retry"
                ) from None
        if self.ladder is not None:
            # observe only ADMITTED shapes (after the put): shed/rejected
            # submits must not skew the learned mix toward traffic that was
            # never served
            self.ladder.observe(params.N, params.K)
        return fut

    def _cover_must_fit(self, must_fit) -> tuple[tuple[int, int], ...]:
        """Union ``must_fit`` with the current ladder's cover shape so a refit
        never shrinks coverage: any request admissible before the swap stays
        admissible after it. Without this, a refit racing in-flight submitters
        can learn a ladder from only the shapes observed SO FAR and a
        concurrent admission of a not-yet-observed (but previously covered)
        shape fails prepare with "no bucket fits"."""
        current = self.service.cfg.buckets
        if not current:
            return tuple(must_fit)
        cover = (max(b.N for b in current), max(b.K for b in current))
        return tuple(must_fit) + (cover,)

    def refit(self, must_fit=()) -> LadderSnapshot:
        """Re-learn the bucket ladder from the shapes observed so far and
        swap it into the service (between-epochs hook; requires a
        `LadderLearner`). Safe while serving: queued requests keep their
        admitted buckets, new admissions pad into the refit ladder, and the
        learned ladder always retains the current ladder's cover shape so
        racing submitters of not-yet-observed shapes stay admissible."""
        if self.ladder is None:
            raise RuntimeError("RealClockDriver was built without a LadderLearner")
        snap = self.ladder.refit(must_fit=self._cover_must_fit(must_fit))
        # NamedTuple._replace-based swap is a single attribute store =>
        # atomic under the GIL; prepare() on caller threads sees either
        # ladder, and both pad into valid, solvable buckets
        self.service.set_buckets(snap.buckets)
        return snap

    def _maybe_auto_refit(self) -> None:
        """Solver-thread drift check (see `DriverConfig.refit_waste_threshold`):
        every ``refit_check_every`` admissions, score the observed mix's waste
        under the service's current ladder and refit when it drifts past the
        threshold. A refit that learns the same ladder back skips the swap so
        a stable-but-wasteful mix triggers at most one executable-cache churn.
        """
        cfg = self.cfg
        if (
            self.ladder is None
            or cfg.refit_waste_threshold is None
            or self._admitted < self._next_refit_check
        ):
            return
        current = self.service.cfg.buckets
        if current is None:
            return                      # exact-shape service: nothing to swap
        counts = self.ladder.counts()
        if sum(counts.values()) < cfg.refit_min_samples:
            # observe() runs on caller threads after the enqueue, so counts
            # can trail admissions; retry next loop instead of consuming the
            # check (bumping here could skip the only drift check a short
            # stream ever gets)
            return
        self._next_refit_check = self._admitted + cfg.refit_check_every
        waste = LadderLearner._waste_or_inf(counts, current)
        if waste > cfg.refit_waste_threshold:
            snap = self.ladder.refit(must_fit=self._cover_must_fit(()))
            if tuple(snap.buckets) != tuple(current):
                self.service.set_buckets(snap.buckets)
                self.auto_refits += 1

    def start(self) -> None:
        if not self._started:
            self._started = True
            self._thread.start()

    def close(self, timeout: float | None = None) -> None:
        """Graceful shutdown: fence off admission, drain the queue AND every
        bucket, resolve all futures, join the solver thread. Idempotent.
        Raises TimeoutError if the drain outlives ``timeout`` seconds, and
        re-raises (wrapped) any error that killed the solver thread.

        Note: a submit() parked on a full queue with no running solver
        (``start=False`` + ``block=True`` + no ``submit_timeout_s``) holds
        the admission fence and would block close(); give blocking submits a
        timeout or start the solver before closing in that configuration."""
        with self._fence:
            first = not self._closed.is_set()
            self._closed.set()
        if not self._started:
            # never-started driver (e.g. backpressure tests): drain inline
            self._admit_pending()
            self._resolve(self.service.drain(self.now())[0])
            return
        if first:
            # sentinel after the flag: admissions racing close() either raise
            # or land before the sentinel and are drained below
            self._inbox.put(_SENTINEL)
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"driver drain did not finish within {timeout}s")
        if self._error is not None:
            self._fail_inflight(self._error)   # catch post-death stragglers
            raise RuntimeError(
                "driver solver thread died; in-flight requests were failed"
            ) from self._error
        # post-join sweep: submit() only enqueues under the fence after
        # re-checking the closed flag, so with the flag set and the thread
        # joined the inbox is final — catch any admission that slipped in
        # between the solver's last drain and its exit
        with self._fence:
            if self._admit_pending() or self.service.pending():
                self._resolve(self.service.drain(self.now())[0])

    def __enter__(self) -> "RealClockDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def summary(self) -> dict:
        """Service metrics plus driver-level admission stats (and warm-start
        cache accounting when the service runs one)."""
        out = {
            **self.service.metrics.summary(),
            "queue_capacity": self.cfg.queue_capacity,
            "inflight": len(self._tickets),
            "auto_refits": self.auto_refits,
        }
        if self.service.warm_cache is not None:
            out.update(self.service.warm_cache.stats())
        return out

    # -- solver thread -------------------------------------------------------

    def _admit_one(self, item) -> bool:
        """Admit one inbox item; True if it was the shutdown sentinel."""
        if item is _SENTINEL:
            return True
        prepared, fut, t_enq = item
        req_id = self.service.admit(prepared, now=t_enq)
        self._tickets[req_id] = fut
        self._admitted += 1
        return False

    def _admit_pending(self) -> bool:
        """Drain the inbox without blocking; True if a sentinel was seen."""
        stop = False
        while True:
            try:
                item = self._inbox.get_nowait()
            except queue.Empty:
                return stop
            stop = self._admit_one(item) or stop

    def _resolve(self, done: list[Completion]) -> None:
        for c in done:
            self.completions.append(c)
            fut = self._tickets.pop(c.req_id, None)
            if fut is not None:
                fut.set_result(c)

    def _run(self) -> None:
        try:
            self._serve_loop()
        except BaseException as exc:  # never die silently: fail the futures
            # under the fence: a submit() is either mid-put (we wait, then
            # sweep its item) or will re-check the closed flag and raise —
            # no future can be orphaned in the inbox after this handler
            with self._fence:
                self._error = exc
                self._closed.set()    # fence off new admissions
                self._fail_inflight(exc)

    def _serve_loop(self) -> None:
        svc = self.service
        stop = False
        while not stop:
            # the flush_due timer: sleep on the inbox until the earliest
            # bucket deadline (or an idle poll when nothing is pending)
            deadline = svc.next_deadline()
            timeout = (
                self.cfg.idle_poll_s
                if deadline is None
                else max(0.0, deadline - self.now())
            )
            try:
                stop = self._admit_one(self._inbox.get(timeout=timeout))
            except queue.Empty:
                pass
            # burst admission: everything already queued joins this round's
            # flush decision before any solve starts
            stop = self._admit_pending() or stop
            if stop:
                break
            self._maybe_auto_refit()
            done, _ = svc.flush_due(now=self.now())
            self._resolve(done)
        # graceful drain: late admissions that beat the fence, then flush
        # every bucket regardless of fill or deadline
        self._admit_pending()
        self._resolve(svc.drain(now=self.now())[0])

    def _fail_inflight(self, exc: BaseException) -> None:
        """Solver thread died: propagate ``exc`` to every unresolved future
        (admitted or still queued) so no caller hangs on result(); close()
        re-raises the error to the shutdown path."""
        for fut in self._tickets.values():
            if not fut.done():
                fut.set_exception(exc)
        self._tickets.clear()
        while True:
            try:
                item = self._inbox.get_nowait()
            except queue.Empty:
                return
            if item is not _SENTINEL:
                _prepared, fut, _t = item
                if not fut.done():
                    fut.set_exception(exc)
