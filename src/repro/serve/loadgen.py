"""Poisson-arrival load generator for `AllocService` (virtual-clock DES).

Arrivals happen on a *virtual* clock (exponential inter-arrival gaps at a
target rate); solves consume *measured* wall-clock seconds on that same
clock. This hybrid discrete-event simulation gives reproducible arrival
patterns while charging the service its true compute cost — so throughput
and tail latency are honest, but a 100 req/s experiment doesn't need 100
real req/s of wall time.

Event loop semantics (single server): the next event is either the next
arrival or the earliest bucket deadline; a size-triggered flush runs
immediately after the admitting arrival; while a batch solves, the clock
advances by the measured solve time, so requests arriving "during" a solve
accrue queue wait exactly as they would against a busy real server.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import numpy as np

from repro.core import SystemParams, Weights
from repro.scenarios import get_family

from .service import AllocService, Completion


def poisson_arrivals(key: jax.Array, n: int, rate_hz: float) -> np.ndarray:
    """n arrival times (seconds, ascending) of a Poisson process at rate_hz."""
    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be > 0, got {rate_hz}")
    gaps = np.asarray(jax.random.exponential(key, (n,))) / rate_hz
    return np.cumsum(gaps)


def scenario_stream(
    key: jax.Array, n: int, *, scenario: str = "iid_rayleigh", **kwargs
) -> list[SystemParams]:
    """Request stream drawn from a registered scenario family by name.

    Thin resolver over ``get_family(scenario).stream`` so serving callers
    (CLI, benchmarks) pick the workload with a string. Stateful families
    (``gauss_markov``) return time-correlated traces; the default redraws
    i.i.d. per request. Deterministic in ``key`` either way, which is what
    lets the real-clock smoke replay the identical stream virtually.
    """
    return get_family(scenario).stream(key, n, **kwargs)


class LoadResult(NamedTuple):
    completions: list          # list[Completion], completion order
    throughput_rps: float      # completed / makespan
    makespan_s: float          # first arrival -> last completion (virtual)
    busy_s: float              # total solve wall time charged to the clock
    summary: dict              # ServiceMetrics.summary() snapshot


def run_load(
    service: AllocService,
    requests: list[SystemParams],
    arrivals,
    weights: list[Weights] | None = None,
    warm_starts: list | None = None,
    accuracies: list | None = None,
    tenants: list | None = None,
) -> LoadResult:
    """Drive ``service`` with ``requests[i]`` arriving at ``arrivals[i]``.

    Returns every completion (the run always drains). ``weights`` optionally
    carries per-request objective weights; ``warm_starts`` optionally injects
    explicit warm-start entries per request (None entries stay cold) — this
    is how a virtual replay reproduces a real-clock warm run exactly: cache
    contents are timing-dependent, so the replay re-injects the RECORDED
    `Completion.warm_start` entries instead of relying on its own cache.
    ``accuracies``/``tenants`` optionally carry each request's A(rho) fit or
    tenant id (`AllocService.prepare` resolution) so a mixed-tenant stream —
    e.g. one recorded off a multi-job driver — replays each request under
    the same belief it was originally solved with.
    """
    if len(requests) != len(arrivals):
        raise ValueError(
            f"requests ({len(requests)}) and arrivals ({len(arrivals)}) differ"
        )
    if weights is not None and len(weights) != len(requests):
        # fail at admission, not with an IndexError mid-run
        raise ValueError(
            f"weights ({len(weights)}) and requests ({len(requests)}) differ"
        )
    if warm_starts is not None and len(warm_starts) != len(requests):
        raise ValueError(
            f"warm_starts ({len(warm_starts)}) and requests ({len(requests)}) differ"
        )
    if accuracies is not None and len(accuracies) != len(requests):
        raise ValueError(
            f"accuracies ({len(accuracies)}) and requests ({len(requests)}) differ"
        )
    if tenants is not None and len(tenants) != len(requests):
        raise ValueError(
            f"tenants ({len(tenants)}) and requests ({len(requests)}) differ"
        )
    arrivals = [float(t) for t in arrivals]
    if any(b < a for a, b in zip(arrivals, arrivals[1:])):
        raise ValueError("arrivals must be non-decreasing")

    clock = 0.0
    busy_total = 0.0
    completions: list[Completion] = []
    i, n = 0, len(requests)

    def admit_through(t: float) -> int:
        """Admit every arrival with t_arr <= t; returns the new stream index.

        Arrivals are physical events: everything with t_arr <= clock already
        happened (possibly while the server was busy solving) and must be in
        the queues before any flush decision at `clock` — including arrivals
        landing *exactly* on a bucket deadline (regression: the deadline
        branch used to flush first, so a tied arrival missed its batch).
        """
        nonlocal i
        while i < n and arrivals[i] <= t:
            service.submit(
                requests[i],
                weights[i] if weights is not None else None,
                now=arrivals[i],
                warm_start=warm_starts[i] if warm_starts is not None else None,
                accuracy=accuracies[i] if accuracies is not None else None,
                tenant=tenants[i] if tenants is not None else None,
            )
            i += 1
        return i

    while i < n or service.pending() > 0:
        admit_through(clock)
        # full buckets flush first — at saturation this is what fills batches
        done, busy = service.flush_full(now=clock)
        if not done:
            deadline = service.next_deadline()
            t_arr = arrivals[i] if i < n else None
            if deadline is not None and (t_arr is None or deadline <= t_arr):
                clock = max(clock, deadline)
                # an arrival tied with the deadline (t_arr == clock) belongs
                # in the queues before the flush decision at `clock`
                admit_through(clock)
                done, busy = service.flush_due(now=clock)
            elif t_arr is not None:
                clock = max(clock, t_arr)   # idle until the next arrival
                continue
        completions.extend(done)
        clock += busy
        busy_total += busy

    makespan = max((clock - arrivals[0]), 1e-12) if arrivals else 0.0
    return LoadResult(
        completions=completions,
        throughput_rps=len(completions) / makespan if makespan else 0.0,
        makespan_s=makespan,
        busy_s=busy_total,
        summary=service.metrics.summary(),
    )
