"""asyncio-native facade over the threaded `RealClockDriver`.

The driver's public surface is thread-blocking: `submit` can park on the
bounded admission queue (backpressure) and returns a
`concurrent.futures.Future`; `close` joins the solver thread. Embedding it
in an async server (the ROADMAP's PR 5 leftover) therefore needs both moves
off the event loop:

* ``await facade.submit(params)`` runs the driver's blocking `submit` in the
  loop's default executor (so a full admission queue suspends the coroutine,
  not the loop) and then awaits the returned future via
  `asyncio.wrap_future` — the solver thread resolving it wakes the loop.
* ``async with AsyncAllocDriver(service) as facade:`` starts the underlying
  driver on entry and runs its draining `close` in the executor on exit.

The facade adds no policy of its own: every queue, batch and equivalence
property is the wrapped driver's. Sync code (e.g. `fl.alloc_backend`'s
`ServiceBackend`) can reach the wrapped driver at ``facade.driver``.
"""
from __future__ import annotations

import asyncio

from repro.core import SystemParams, Weights

from .driver import DriverConfig, RealClockDriver
from .ladder import LadderLearner
from .service import AllocService, Completion


class AsyncAllocDriver:
    """`RealClockDriver` with an asyncio face (see module docstring).

    Construct from a sans-IO `AllocService` (a driver is created, not yet
    started — enter the context or call `start`) or wrap an existing
    `RealClockDriver` (sharing it with sync callers; the context manager
    still closes it on exit, so only the owner should exit the context).
    """

    def __init__(
        self,
        target: AllocService | RealClockDriver,
        cfg: DriverConfig = DriverConfig(),
        ladder: LadderLearner | None = None,
    ):
        if isinstance(target, RealClockDriver):
            self.driver = target
        else:
            self.driver = RealClockDriver(target, cfg, ladder, start=False)

    @property
    def service(self) -> AllocService:
        return self.driver.service

    def start(self) -> "AsyncAllocDriver":
        self.driver.start()
        return self

    async def submit(
        self,
        params: SystemParams,
        weights: Weights | None = None,
        warm_start=None,
        accuracy=None,
        tenant=None,
    ) -> Completion:
        """Admit one scenario and await its `Completion`.

        Backpressure-safe: the blocking enqueue runs in the executor, and
        the solve itself is awaited through the driver's future — the event
        loop stays free for other coroutines while the solver thread works.
        ``warm_start``/``accuracy``/``tenant`` pass through to
        `RealClockDriver.submit` (explicit warm-start entries overriding any
        cache lookup; the per-tenant A(rho) fit stamped at prepare).
        """
        loop = asyncio.get_running_loop()
        fut = await loop.run_in_executor(
            None, self.driver.submit, params, weights, warm_start, accuracy, tenant
        )
        return await asyncio.wrap_future(fut)

    async def close(self, timeout: float | None = None) -> None:
        """Graceful drain (`RealClockDriver.close`) off the event loop."""
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self.driver.close, timeout)

    async def __aenter__(self) -> "AsyncAllocDriver":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()
