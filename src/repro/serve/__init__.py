"""Allocation serving layer: micro-batched scenario service over `solve_batch`.

The pipeline is  request -> `pad_params` into a `ShapeBucket` -> per-bucket
admission queue (`MicroBatcher`) -> one AOT-compiled `solve_batch` executable
per (bucket, batch-slots, AllocatorConfig) -> hardened exact-shape
`Allocation` back to the caller (scored through the batched
`kernels/fedsem_objective` evaluator, `Completion.objective`), with p50/p95
latency, queue-depth and batch-occupancy metrics along the way.

Layer-wide equivalence contract: padding (shape buckets), co-batching
(micro-batches), sharding (`shard_batch`) and the kernel objective path are
all *transparent* — each request's hardened allocation and objective match a
solo exact-shape `solve` to float32 round-off, asserted respectively in
`tests/test_serve_alloc.py`, `tests/test_distribute.py` and
`tests/test_kernels.py`.
"""
from .batching import BatchPolicy, MicroBatcher, PendingRequest
from .loadgen import LoadResult, poisson_arrivals, run_load
from .metrics import ServiceMetrics, percentile
from .service import AllocService, Completion, ServeConfig

__all__ = [
    "AllocService", "Completion", "ServeConfig",
    "BatchPolicy", "MicroBatcher", "PendingRequest",
    "ServiceMetrics", "percentile",
    "LoadResult", "poisson_arrivals", "run_load",
]
