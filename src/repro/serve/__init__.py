"""Allocation serving layer: micro-batched scenario service over `solve_batch`.

The pipeline is  request -> `pad_params` into a `ShapeBucket` -> per-bucket
admission queue (`MicroBatcher`) -> one AOT-compiled `solve_batch` executable
per (bucket, batch-slots, AllocatorConfig) -> hardened exact-shape
`Allocation` back to the caller, with p50/p95 latency, queue-depth and
batch-occupancy metrics along the way.
"""
from .batching import BatchPolicy, MicroBatcher, PendingRequest
from .loadgen import LoadResult, poisson_arrivals, run_load
from .metrics import ServiceMetrics, percentile
from .service import AllocService, Completion, ServeConfig

__all__ = [
    "AllocService", "Completion", "ServeConfig",
    "BatchPolicy", "MicroBatcher", "PendingRequest",
    "ServiceMetrics", "percentile",
    "LoadResult", "poisson_arrivals", "run_load",
]
