"""Allocation serving layer: micro-batched scenario service over `solve_batch`.

The pipeline is  request -> `pad_params` into a `ShapeBucket` -> per-bucket
admission queue (`MicroBatcher`) -> one AOT-compiled `solve_batch` executable
per (bucket, batch-slots, AllocatorConfig) -> hardened exact-shape
`Allocation` back to the caller (scored through the batched
`kernels/fedsem_objective` evaluator, `Completion.objective`), with p50/p95
latency, queue-depth and batch-occupancy metrics along the way.

Two drivers sit on top of the sans-IO core: the virtual-clock load generator
(`loadgen.run_load`, reproducible DES for tests/benchmarks) and the
real-clock threaded `driver.RealClockDriver` (bounded admission queue,
solver thread, deadline timer, graceful drain). `ladder.LadderLearner`
learns an autoscaling `ShapeBucket` ladder from the observed shape mix.
`warmstart.WarmStartCache` closes the recurring-user loop: completed
hardened solutions are recorded under a quantized channel/accuracy signature
and re-enter later solves as an extra multi-start candidate — never-worse by
the multi-start dominance argument, bit-identical to the cold path when
disabled or missing.

Layer-wide equivalence contract: padding (shape buckets), co-batching
(micro-batches), sharding (`shard_batch`), the kernel objective path and the
real-clock driver are all *transparent* — each request's hardened allocation
and objective match a solo exact-shape `solve` to float32 round-off,
asserted respectively in `tests/test_serve_alloc.py`,
`tests/test_distribute.py`, `tests/test_kernels.py` and
`tests/test_serve_driver.py`.
"""
from .aio import AsyncAllocDriver
from .batching import BatchPolicy, MicroBatcher, PendingRequest
from .driver import (
    AdmissionQueueFull, DriverClosed, DriverConfig, RealClockDriver,
    pace_stream, same_hardened_assignments,
)
from .ladder import (
    LadderLearner, LadderSnapshot, learn_buckets, padded_area_waste,
)
from .loadgen import LoadResult, poisson_arrivals, run_load, scenario_stream
from .metrics import Reservoir, ServiceMetrics, percentile
from .service import AllocService, Completion, ServeConfig
from .warmstart import (
    CacheEntry, WarmStartCache, WarmStartConfig, batch_starts,
    entry_from_alloc, iters_to_converge, pad_start, request_signature,
)

__all__ = [
    "AllocService", "Completion", "ServeConfig",
    "WarmStartCache", "WarmStartConfig", "CacheEntry", "request_signature",
    "entry_from_alloc", "pad_start", "batch_starts", "iters_to_converge",
    "BatchPolicy", "MicroBatcher", "PendingRequest",
    "ServiceMetrics", "Reservoir", "percentile",
    "LoadResult", "poisson_arrivals", "run_load", "scenario_stream",
    "AsyncAllocDriver",
    "RealClockDriver", "DriverConfig", "AdmissionQueueFull", "DriverClosed",
    "pace_stream", "same_hardened_assignments",
    "LadderLearner", "LadderSnapshot", "learn_buckets", "padded_area_waste",
]
