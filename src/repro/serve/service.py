"""`AllocService`: micro-batched scenario-allocation serving over `solve_batch`.

Heterogeneous `SystemParams` requests are padded into canonical `ShapeBucket`s
(`pad_params` masks keep padding inert), queued per bucket, and flushed
through ONE AOT-compiled `solve_batch` executable per (bucket, batch-slots,
`AllocatorConfig`, mesh). The batch axis is padded to a fixed number of slots
by replicating the last request, so each bucket compiles exactly once no
matter how full its flushes run — the compiled-executable cache is the whole
point: steady-state serving never re-traces. With ``shard_batch`` the slots
grow to ``device_count x max_batch`` and each flush runs one scenario-sharded
executable over all local devices (`core.distribute`).

Equivalence guarantees this layer asserts (tests/test_serve_alloc.py):
a padded-bucket solve returns the *same hardened assignment* as the
exact-shape solve of the submitted scenario, with objective drift at float32
round-off; batch-axis padding replicates the tail request, whose replicas are
solved and discarded, so co-batching never changes any caller's answer.
Each flushed bucket batch is also *scored* through the batched
`kernels/fedsem_objective` evaluator (`core.scoring.batch_objectives`) in one
fused call over the padded batch — `Completion.objective` reports the
eq. 13 value of the returned allocation, equal to `system.objective` on the
exact-shape scenario to float32 round-off.

The A(rho) accuracy model is PER-REQUEST, not service-global: every request
is stamped with its own `AccuracyFn` at `prepare` (explicit ``accuracy=``
arg > per-tenant registry (`set_accuracy(acc, tenant=...)`) > the service
default), the flush stacks the per-row fits (`stack_accuracy`) and the AOT
executables take the stacked fit as a runtime argument
(``exe(pb, wb, accb)``, `solve_batch(..., acc_batched=True)`), so co-batched
tenants with different beliefs solve AND score under their own model in one
program — a refit never recompiles and never touches a co-tenant's rows
(the multi-tenant equivalence rows, tests/test_multitenant_accuracy.py).

The service is sans-IO: callers pass ``now`` timestamps and decide when to
flush (`flush_full` after submits, `flush_due` on timer ticks, `drain` at
shutdown), which makes it drivable by a real clock (`repro.launch.serve_alloc`)
or a virtual one (`repro.serve.loadgen`, benchmarks).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import NamedTuple

import jax
import numpy as np

from repro.core import (
    Allocation,
    AllocatorConfig,
    SystemParams,
    Weights,
    bucket_for,
    pad_params,
    scenario_mesh,
    scenario_sharding,
    sharded_batch_solver,
    stack_params,
    stack_weights,
    tree_index,
    unpad_alloc,
)
from repro.core.accuracy import AccuracyFn, default_accuracy, stack_accuracy
from repro.core.allocator import (
    _refine_batch_jit,
    _solve_batch_impl,
    _solve_batch_jit,
    sharded_refine_solver,
)
from repro.core.distribute import replicated
from repro.core.scoring import batch_objectives
from repro.core.types import DEFAULT_BUCKETS, ShapeBucket

from .batching import BatchPolicy, MicroBatcher, PendingRequest
from .metrics import ServiceMetrics
from .warmstart import (
    CacheEntry,
    WarmStartCache,
    WarmStartConfig,
    batch_starts,
    entry_from_alloc,
    iters_to_converge,
    request_signature,
)


class ServeConfig(NamedTuple):
    policy: BatchPolicy = BatchPolicy()
    #: bucket ladder; None = exact shapes (no padding — every distinct request
    #: shape compiles its own program; the solve-per-request baseline)
    buckets: tuple[ShapeBucket, ...] | None = DEFAULT_BUCKETS
    allocator: AllocatorConfig = AllocatorConfig(inner="pgd")
    #: pad the batch axis to ``policy.max_batch`` slots so each bucket
    #: compiles once; False recompiles per observed batch size
    pad_batch: bool = True
    #: shard the batch axis over a scenario mesh of all local devices
    #: (`core.distribute`): bucket slots grow to ``device_count x max_batch``
    #: (``policy.max_batch`` becomes the per-device batch) and each flush runs
    #: one sharded executable with no cross-device communication
    shard_batch: bool = False
    #: score every flushed bucket batch through the batched
    #: `kernels/fedsem_objective` evaluator (one fused call per flush) and
    #: report the eq. 13 value on each `Completion.objective`
    score_objective: bool = True
    #: warm-start solution-reuse cache (`repro.serve.warmstart`): record each
    #: completed request's hardened solution under a quantized channel/
    #: accuracy signature and inject hits into later flushes as an extra
    #: multi-start candidate. None (default) disables it — the cold path,
    #: bit-for-bit (the cold==disabled equivalence row)
    warmstart: WarmStartConfig | None = None


#: one fused batched-kernel scoring call per flush; jit-cached per bucket
#: shape (a tiny program next to the solver executables)
_score_flush = jax.jit(functools.partial(batch_objectives, weights_batched=True))


def _round_sig(x: float, digits: int = 12) -> float:
    """Round to ``digits`` significant figures (canonical bucket-key floats).

    Requests built from the same per-subcarrier bandwidth but different K
    reconstruct the padded ``B = bbar * K_pad`` through different float
    round-trips and can disagree by an ulp; keyed raw, they would silently
    land in different queues (and `stack_params` would reject mixing them).
    12 significant figures absorbs ulp noise (~1e-16 rel) while keeping any
    physically distinct bandwidth (>= 1e-10 rel apart) distinct.
    """
    if x == 0.0 or not math.isfinite(x):
        return x
    return round(x, digits - 1 - math.floor(math.log10(abs(x))))


class Completion(NamedTuple):
    """One answered request (exact-shape, hardened, feasible-by-construction)."""

    req_id: int
    alloc: Allocation
    bucket: tuple       # (N_pad, K_pad)
    latency_s: float    # arrival -> answer (queue wait + batched solve)
    wait_s: float       # arrival -> flush
    solve_s: float      # the batched solve this request rode in
    #: eq. 13 objective of ``alloc``, scored on the padded bucket batch by the
    #: batched kernel (== `system.objective` on the exact-shape scenario to
    #: float32 round-off); None when ``ServeConfig.score_objective`` is off
    objective: float | None = None
    #: True when this request rode a warm-start candidate (cache hit or
    #: explicit injection) into its flush
    warm_hit: bool = False
    #: the exact-shape warm-start entry (or tuple of entries, top-k) that
    #: rode along (None for a cold request). Recorded so a virtual-clock
    #: replay can re-inject the SAME starts explicitly — real==virtual
    #: equivalence stays exact even though cache contents are
    #: timing-dependent (batch boundaries move)
    warm_start: CacheEntry | tuple | None = None


class AllocService:
    """Micro-batched allocation server (see module docstring)."""

    def __init__(
        self,
        cfg: ServeConfig = ServeConfig(),
        executables: dict[tuple, object] | None = None,
    ):
        """``executables`` optionally shares a compiled-solver cache built by
        another service with the SAME ServeConfig (e.g. a warmed instance in a
        benchmark sweep); the dict is used and extended in place."""
        self.cfg = cfg
        # with shard_batch, policy.max_batch is the PER-DEVICE batch: buckets
        # fill (and pad) to device_count x max_batch slots, so each device in
        # the sharded executable solves a max_batch-sized sub-batch
        self.mesh = scenario_mesh() if cfg.shard_batch else None
        n_dev = self.mesh.size if self.mesh is not None else 1
        self._full_slots = cfg.policy.max_batch * n_dev
        self.batcher = MicroBatcher(cfg.policy._replace(max_batch=self._full_slots))
        self.metrics = ServiceMetrics()
        self._executables = executables if executables is not None else {}
        #: all-tenants default A(rho); per-tenant overrides live in
        #: `_tenant_acc` and win for their own tenant's admissions
        self._acc = default_accuracy()
        self._tenant_acc: dict = {}
        self._next_id = 0
        #: warm-start solution cache (None when disabled). Thread-safe on its
        #: own lock: `prepare` reads it from caller threads, the solver
        #: thread writes it after each flush
        self.warm_cache = (
            WarmStartCache(cfg.warmstart) if cfg.warmstart is not None else None
        )

    @property
    def executables(self) -> dict[tuple, object]:
        """The compiled-solver cache, keyed by (bucket key, batch slots,
        AllocatorConfig, mesh) — pass to another AllocService to skip its
        compiles; a service with a different allocator config or sharding
        (``shard_batch``, so mesh None vs a scenario mesh) safely misses and
        compiles its own entries."""
        return self._executables

    # -- admission ----------------------------------------------------------

    def _pad(self, params: SystemParams) -> SystemParams:
        # canonicalise B at the service boundary — in BOTH bucket modes — so
        # equal-bbar requests that reconstructed B through different float
        # round-trips land in one queue (see `_round_sig`). Exact-shape mode
        # used to skip this: two requests whose B differed by an ulp got equal
        # shapes but different bucket keys, and even with equal keys
        # `stack_params` would reject mixing them (regression-tested).
        # The core `pad_params` itself stays bit-exact on bbar.
        if self.cfg.buckets is None:
            return dataclasses.replace(params, B=_round_sig(params.B))
        padded = pad_params(params, bucket_for(params.N, params.K, self.cfg.buckets))
        return dataclasses.replace(padded, B=_round_sig(padded.B))

    @staticmethod
    def _bucket_key(padded: SystemParams) -> tuple:
        # shape + every static meta field: one queue == one compiled program
        return (
            padded.N, padded.K, padded.B, padded.N0,
            padded.xi, padded.eta, padded.q,
        )

    def _resolve_accuracy(self, accuracy=None, tenant=None) -> AccuracyFn:
        """The A(rho) fit a request is stamped with at admission: an explicit
        ``accuracy`` wins, else the ``tenant``'s registered fit
        (`set_accuracy(acc, tenant=...)`), else the all-tenants default."""
        if accuracy is not None:
            return accuracy
        if tenant is not None and tenant in self._tenant_acc:
            return self._tenant_acc[tenant]
        return self._acc

    def prepare(
        self,
        params: SystemParams,
        weights: Weights | None = None,
        warm_start=None,
        accuracy: AccuracyFn | None = None,
        tenant=None,
    ) -> PendingRequest:
        """Pad/canonicalise one scenario into its bucket WITHOUT touching any
        queue state (``req_id``/``arrival_t`` are placeholders until `admit`).

        This is the pure, stateless half of admission: the real-clock driver
        runs it on the *caller's* thread, so the host-side padding work
        overlaps the solver thread's device solves (which release the GIL).
        The request's A(rho) fit is resolved and STAMPED here
        (`_resolve_accuracy`) — it rides the request to its flush, so a
        `set_accuracy` racing the queue never re-steers or re-scores an
        already-admitted request. The warm-cache lookup happens here too (the
        cache has its own lock), keyed on the request's OWN fit: an explicit
        ``warm_start`` entry (or tuple of entries) — e.g. the previous FL
        round's solution, or a replay re-injecting recorded hits — takes
        precedence over whatever the cache holds."""
        w = weights if weights is not None else Weights.ones()
        acc = self._resolve_accuracy(accuracy, tenant)
        sig = None
        if self.warm_cache is not None:
            sig = request_signature(params, w, acc, self.cfg.warmstart)
        entry = warm_start
        # CacheEntry IS a tuple (NamedTuple): only normalise genuine
        # candidate lists, never a bare entry
        if isinstance(entry, (list, tuple)) and not isinstance(entry, CacheEntry):
            entry = tuple(entry) if entry else None
        if entry is None and self.warm_cache is not None:
            hits = self.warm_cache.lookup(sig, self.cfg.warmstart.top_k)
            entry = hits[0] if len(hits) == 1 else (tuple(hits) or None)
        return PendingRequest(
            req_id=-1,
            params=params,
            padded=self._pad(params),
            weights=w,
            arrival_t=0.0,
            accuracy=acc,
            warm_start=entry,
            warm_sig=sig,
        )

    def admit(self, req: PendingRequest, now: float) -> int:
        """Assign a request id and enqueue a `prepare`d request (arrival
        stamped at ``now``). Cheap — a deque append — and, like every other
        state mutation on this sans-IO service, must be called from a single
        thread (the driver's solver thread)."""
        req.req_id = self._next_id
        self._next_id += 1
        req.arrival_t = now
        self.batcher.add(self._bucket_key(req.padded), req)
        self.metrics.observe_submit(self.batcher.depth())
        return req.req_id

    def submit(
        self,
        params: SystemParams,
        weights: Weights | None = None,
        now: float = 0.0,
        warm_start=None,
        accuracy: AccuracyFn | None = None,
        tenant=None,
    ) -> int:
        """Admit one scenario; returns its request id. Does not solve — call
        `flush_full` / `flush_due` / `drain` to get completions.
        ``accuracy``/``tenant`` select the A(rho) fit the request solves
        under (see `prepare`)."""
        return self.admit(
            self.prepare(params, weights, warm_start, accuracy, tenant), now
        )

    def set_buckets(self, buckets: tuple[ShapeBucket, ...] | None) -> None:
        """Swap the bucket ladder (e.g. a learned `repro.serve.ladder` refit
        between epochs). Safe mid-stream: already-queued requests keep the
        bucket they were admitted into (their padded params and key travel
        with them), only new admissions see the new ladder, and the
        executable cache simply compiles entries for new buckets on first
        flush (old entries stay valid)."""
        self.cfg = self.cfg._replace(buckets=buckets)

    def set_accuracy(self, acc, tenant=None) -> None:
        """Update the A(rho) model subsequent ADMISSIONS are stamped with
        (e.g. an `AccuracyFn` re-fit from a SemCom job's own proxy-accuracy
        measurements — the FedSem feedback edge, `repro.fl.semcom_job`).

        With ``tenant`` the refit scopes to that tenant's registry entry:
        only requests admitted under the same tenant id (or with this fit
        passed explicitly) see it — co-tenants on a shared driver keep their
        own beliefs, bit-for-bit (the multi-tenant non-interference row).
        Without ``tenant`` the all-tenants DEFAULT is swapped — the legacy
        service-global behaviour, which unregistered-tenant requests keep
        getting unchanged (the compatibility shim, pinned by regression).

        Zero recompiles either way: the stacked per-row fit is a runtime
        argument of every compiled executable, not part of its cache key, so
        a refit is a dict/attribute store (atomic under the GIL, same safety
        argument as `set_buckets`). Requests stamp their fit at `prepare` —
        already-queued requests solve and score under the model they were
        admitted with, not the refit.

        Warm-start cache entries recorded under the OLD model stay valid and
        need no invalidation: a hit is only ever a *start point* — the refine
        pass re-solves and re-scores it under the rider's current fit, so
        a stale entry competes on the new objective and can only help or tie
        (regression-tested in tests/test_warmstart.py).
        """
        if tenant is None:
            self._acc = acc
        else:
            self._tenant_acc[tenant] = acc

    def pending(self) -> int:
        return self.batcher.depth()

    def next_deadline(self) -> float | None:
        return self.batcher.next_deadline()

    # -- the compiled-solver cache ------------------------------------------

    def _slots(self, n_real: int) -> int:
        """Batch-axis slots for a flush of ``n_real`` requests.

        ``pad_batch``: fixed at ``device_count x max_batch`` so each bucket
        compiles once. Otherwise slots follow the observed size, rounded up to
        the device count when sharding (the mesh needs a divisible axis).
        """
        if self.cfg.pad_batch:
            return self._full_slots
        if self.mesh is not None:
            n_dev = self.mesh.size
            return -(-n_real // n_dev) * n_dev
        return n_real

    def _place(self, params_batch, weights_batch, acc_batch):
        """Commit a flush's inputs to the mesh (scenario-sharded batch axis —
        including the stacked per-row accuracy fit, whose leaves are (B,))
        so AOT executables see the shardings they were compiled for. No-op
        placement cost on a single device."""
        if self.mesh is None:
            return params_batch, weights_batch, acc_batch
        scen = scenario_sharding(self.mesh)
        return (
            jax.device_put(params_batch, scen),
            jax.device_put(weights_batch, scen),
            jax.device_put(acc_batch, scen),
        )

    def _solver(self, key: tuple, slots: int, params_batch, weights_batch, acc_batch):
        # AllocatorConfig AND the mesh are part of the key: a shared
        # `executables` dict must never hand config A's solver to a service
        # running config B, nor a single-device program to a sharded service
        cache_key = (key, slots, self.cfg.allocator, self.mesh)
        exe = self._executables.get(cache_key)
        if exe is None:
            cfg = self.cfg.allocator
            jitted = (
                _solve_batch_jit
                if self.mesh is None
                else sharded_batch_solver(self.mesh, True, True)
            )
            pb, wb, accb = self._place(params_batch, weights_batch, acc_batch)
            t0 = time.perf_counter()
            exe = jitted.lower(pb, wb, accb, cfg, True, True).compile()
            self._executables[cache_key] = exe
            self.metrics.observe_cache(hit=False, compile_s=time.perf_counter() - t0)
        else:
            self.metrics.observe_cache(hit=True)
        return exe

    def _place_extra(self, extra):
        """Commit a flush's warm-start batch to the device(s) the executables
        expect (scenario-sharded like the params when running on a mesh)."""
        if self.mesh is None:
            return jax.tree.map(jax.numpy.asarray, extra)
        return jax.device_put(extra, scenario_sharding(self.mesh))

    def _refiner(self, key: tuple, slots: int, pb, wb, accb, extra):
        """AOT-compiled warm-refine executable for one (bucket, slots,
        candidate-count) triple — the second program of a warm flush: takes
        the cold result plus the flush's `ExtraStart` batch and returns the
        per-scenario best (`core.allocator._refine_batch_impl`). Cached
        beside the cold executables under a distinct key so cold-only
        services never pay its compile, and flushes with zero hits never run
        it. Single-candidate flushes ((B,)-valid `ExtraStart`) and top-k
        flushes ((B, top_k)) are different programs; `batch_starts` pads
        every multi-candidate flush to exactly ``top_k`` candidates, so a
        service compiles at most two refine programs per bucket."""
        n_cand = 1 if np.ndim(extra.valid) == 1 else int(extra.valid.shape[1])
        cache_key = (key, slots, self.cfg.allocator, self.mesh, "warm-refine", n_cand)
        exe = self._executables.get(cache_key)
        if exe is None:
            cfg = self.cfg.allocator
            jitted = (
                _refine_batch_jit
                if self.mesh is None
                else sharded_refine_solver(self.mesh, True, True)
            )
            pb, wb, accb = self._place(pb, wb, accb)
            extra = self._place_extra(extra)
            # the cold result's abstract shape is all lowering needs — no
            # solve happens here, so compile time stays out of solve_s
            base = jax.eval_shape(
                functools.partial(
                    _solve_batch_impl, cfg=cfg, weights_batched=True,
                    acc_batched=True,
                ),
                pb, wb, accb,
            )
            t0 = time.perf_counter()
            exe = jitted.lower(pb, wb, accb, extra, base, cfg, True, True).compile()
            self._executables[cache_key] = exe
            self.metrics.observe_cache(hit=False, compile_s=time.perf_counter() - t0)
        else:
            self.metrics.observe_cache(hit=True)
        return exe

    def warmup(self, example_params) -> None:
        """Pre-compile executables for the buckets the given example scenarios
        land in (serving warm-up, so first requests don't pay compile time).

        With ``pad_batch=True`` (default) every flush uses ``max_batch`` slots,
        so one compile per bucket covers steady state. With ``pad_batch=False``
        the slot count follows the observed batch size and only single-request
        flushes are prewarmed — larger batches still trace on first sight
        (that recompile churn is why ``pad_batch=False`` is not the default).
        """
        seen: dict[tuple, SystemParams] = {}
        for p in example_params:
            padded = self._pad(p)
            seen.setdefault(self._bucket_key(padded), padded)
        slots = self._slots(1)
        for key, padded in seen.items():
            pb = stack_params([padded] * slots)
            wb = stack_weights([Weights.ones()] * slots)
            accb = stack_accuracy([self._acc] * slots)
            self._solver(key, slots, pb, wb, accb)
            if self.cfg.warmstart is not None:
                # pre-compile the warm-refine program(s) too (a placeholder
                # entry fixes the shapes; contents are irrelevant to tracing)
                dummy = CacheEntry(
                    f=0.5 * np.asarray(padded.f_max, dtype=np.float32),
                    P=np.zeros((padded.N, padded.K), dtype=np.float32),
                    X=np.zeros((padded.N, padded.K), dtype=np.float32),
                    objective=float("nan"),
                )
                extra = batch_starts(
                    [dummy] + [None] * (slots - 1), [padded] * slots
                )
                self._refiner(key, slots, pb, wb, accb, extra)
                top_k = self.cfg.warmstart.top_k
                if top_k > 1:
                    # top-k flushes run the (B, top_k)-candidate program
                    extra_k = batch_starts(
                        [[dummy] * top_k] + [None] * (slots - 1),
                        [padded] * slots,
                        k=top_k,
                    )
                    self._refiner(key, slots, pb, wb, accb, extra_k)

    # -- flushing ------------------------------------------------------------

    def _flush_bucket(self, key: tuple, now: float) -> tuple[list[Completion], float]:
        pending = self.batcher.pop(key)
        n_real = len(pending)
        slots = self._slots(n_real)
        # pad the batch axis by replicating the last request: same shape ->
        # same executable; replicas are solved and discarded
        filled = pending + [pending[-1]] * (slots - n_real)
        pb = stack_params([r.padded for r in filled])
        wb = stack_weights([r.weights for r in filled])
        # each row rides ITS OWN A(rho) fit (stamped at `prepare`) as one row
        # of the stacked runtime accuracy argument — mixed-tenant co-batching
        # solves and scores every request under its own belief
        accb = stack_accuracy(
            [r.accuracy if r.accuracy is not None else self._acc for r in filled]
        )
        exe = self._solver(key, slots, pb, wb, accb)
        # one ExtraStart batch for the flush iff ANY rider has a warm start
        # (`batch_starts` returns None otherwise): a hitless flush runs the
        # UNCHANGED cold executable only — the cold==disabled equivalence row
        # holds per flush, not just per service
        extra = batch_starts(
            [r.warm_start for r in filled],
            [r.padded for r in filled],
            k=self.cfg.warmstart.top_k if self.cfg.warmstart is not None else None,
        )
        if extra is not None:
            refine = self._refiner(key, slots, pb, wb, accb, extra)
            extra = self._place_extra(extra)
        pb, wb, accb = self._place(pb, wb, accb)
        t0 = time.perf_counter()
        if extra is None:
            res = jax.block_until_ready(exe(pb, wb, accb))
        else:
            base = exe(pb, wb, accb)
            res = jax.block_until_ready(refine(pb, wb, accb, extra, base))
        solve_s = time.perf_counter() - t0
        self.metrics.observe_batch(n_real, slots, solve_s)
        # score the padded batch through the batched kernel in one fused call
        # (outside solve_s: diagnostics, not solver latency) — under the same
        # per-row fits the rows were SOLVED with, so a `set_accuracy` racing
        # an in-flight flush can never mis-report `Completion.objective`
        objs = (
            np.asarray(_score_flush(pb, wb, res.alloc, accb))
            if self.cfg.score_objective
            else None
        )

        # convergence traces for the iteration-savings metric (host copy once
        # per flush, only when warm starts are in play on this service)
        traces = (
            np.asarray(res.trace)
            if (self.cfg.warmstart is not None or extra is not None)
            else None
        )
        iters_rtol = (
            self.cfg.warmstart.iters_rtol
            if self.cfg.warmstart is not None
            else WarmStartConfig().iters_rtol
        )

        out = []
        for i, req in enumerate(pending):
            alloc = unpad_alloc(
                tree_index(res.alloc, i), req.params.N, req.params.K
            )
            obj = float(objs[i]) if objs is not None else None
            # record the hardened solution for future requests under this
            # signature (exact shape: one entry serves every covering bucket)
            if self.warm_cache is not None and req.warm_sig is not None:
                self.warm_cache.put(req.warm_sig, entry_from_alloc(alloc, obj))
            if traces is not None:
                self.metrics.observe_warm(
                    hit=req.warm_start is not None,
                    iters=iters_to_converge(traces[i], iters_rtol),
                )
            wait = now - req.arrival_t
            latency = wait + solve_s
            self.metrics.observe_completion(latency, wait)
            out.append(
                Completion(
                    req_id=req.req_id,
                    alloc=alloc,
                    bucket=(key[0], key[1]),
                    latency_s=latency,
                    wait_s=wait,
                    solve_s=solve_s,
                    objective=obj,
                    warm_hit=req.warm_start is not None,
                    warm_start=req.warm_start,
                )
            )
        return out, solve_s

    def _flush_while(self, select, now: float) -> tuple[list[Completion], float]:
        """Flush buckets returned by ``select()`` until none qualify. A queue
        deeper than ``max_batch`` (burst arrivals) flushes in successive
        batches; ``select`` is re-evaluated after every round."""
        completions: list[Completion] = []
        busy = 0.0
        while True:
            keys = select()
            if not keys:
                return completions, busy
            for key in keys:
                # single-server semantics: batches run back-to-back, so
                # requests in a later bucket also wait out earlier solves
                done, solve_s = self._flush_bucket(key, now + busy)
                completions.extend(done)
                busy += solve_s

    def flush_full(self, now: float) -> tuple[list[Completion], float]:
        """Flush buckets that reached ``max_batch``. Returns (completions,
        busy seconds spent solving)."""
        return self._flush_while(self.batcher.full_keys, now)

    def flush_due(self, now: float) -> tuple[list[Completion], float]:
        """Flush buckets that are full or whose oldest request waited out
        ``max_wait_s`` by ``now``."""
        return self._flush_while(lambda: self.batcher.due_keys(now), now)

    def drain(self, now: float) -> tuple[list[Completion], float]:
        """Flush everything (shutdown / end of load run)."""
        return self._flush_while(self.batcher.keys, now)
