"""Gauss-Markov (AR(1)) time-correlated fading family.

`iid_rayleigh` redraws the whole scenario per request, so consecutive serving
requests are statistically independent — unrealistic for a cell whose users
stay put between allocation slots. This family models each subcarrier's
small-scale fading as a first-order Gauss-Markov process on the complex
envelope ``h = (x + iy) / sqrt(2)``:

    x' = corr * x + sqrt(1 - corr^2) * eps,   eps ~ N(0, 1)   (same for y)

so the power gain ``|h|^2 = (x^2 + y^2) / 2`` has the same exponential
(Rayleigh-power) marginal as `iid_rayleigh` at every step — single draws are
distribution-identical to i.i.d. Rayleigh — while successive draws correlate
with coefficient ``corr^2``. Large-scale geometry (positions, shadowing) and
cycle counts are frozen per stream, which is the drift the serving ladder
sees: the shape mix and gain profile wander instead of resampling.

``sample``/``sample_batch`` are stationary (pure in the key, oracle-gated
like every family). ``stream`` is the stateful part: it keeps one fading
state per (N, K) size and advances it each time that size recurs, returning
materialized `SystemParams` so `serve/loadgen`, `RealClockDriver`, and the
real==virtual replay gate consume it unchanged.
"""
from __future__ import annotations

from typing import Iterable

import jax
import jax.numpy as jnp

from repro.core.types import SystemParams, dbm_to_watt

from .base import (
    DEFAULT_STREAM_BBAR,
    DEFAULT_STREAM_SIZES,
    ScenarioFamily,
    _validate_stream,
    register,
)


def _envelope_gain(x: jax.Array, y: jax.Array) -> jax.Array:
    """Power gain of the complex envelope (x + iy)/sqrt(2): exp(1) marginal."""
    return (x * x + y * y) / 2.0


class GaussMarkov(ScenarioFamily):
    name = "gauss_markov"

    def sample(
        self,
        key: jax.Array,
        *,
        N: int = 10,
        K: int = 50,
        B: float = 20e6,
        radius_m: float = 500.0,
        shadowing_db: float = 8.0,
        p_max_dbm: float = 20.0,
        f_max_hz: float = 2e9,
        eta: int = 10,
        d_samples: float = 500.0,
        c_lo: float = 1e4,
        c_hi: float = 3e4,
        D_bits: float = 2.81e4,
        C_round_bits: float = 4.15e6,
        L_rounds: int = 10,
        t_sc_max: float = 20.0,
        q: int = 2,
    ) -> SystemParams:
        """One stationary draw (the AR process's marginal law)."""
        k_pos, k_shadow, k_fade, k_c = jax.random.split(key, 4)
        pl_shadow_db = _large_scale_db(k_pos, k_shadow, N, radius_m, shadowing_db)
        x, y = jax.random.normal(k_fade, (2, N, K))
        gain_lin = 10.0 ** (-pl_shadow_db[:, None] / 10.0) * _envelope_gain(x, y)
        c = jax.random.uniform(k_c, (N,), minval=c_lo, maxval=c_hi)
        return _assemble(
            gain_lin, c, N=N, K=K, B=B, d_samples=d_samples, D_bits=D_bits,
            C_round_bits=C_round_bits, L_rounds=L_rounds, p_max_dbm=p_max_dbm,
            f_max_hz=f_max_hz, t_sc_max=t_sc_max, q=q, eta=eta,
        )

    def stream(
        self,
        key: jax.Array,
        n_requests: int,
        *,
        sizes: Iterable[tuple[int, int]] = DEFAULT_STREAM_SIZES,
        bbar: float = DEFAULT_STREAM_BBAR,
        corr: float = 0.9,
        radius_m: float = 500.0,
        shadowing_db: float = 8.0,
        p_max_dbm: float = 20.0,
        f_max_hz: float = 2e9,
        eta: int = 10,
        d_samples: float = 500.0,
        c_lo: float = 1e4,
        c_hi: float = 3e4,
        D_bits: float = 2.81e4,
        C_round_bits: float = 4.15e6,
        L_rounds: int = 10,
        t_sc_max: float = 20.0,
        q: int = 2,
    ) -> list[SystemParams]:
        """Time-correlated request stream: one persistent user population per
        (N, K) size, AR(1)-advanced each time that size recurs.

        Deterministic in ``key`` (so the real-clock driver's virtual replay
        regenerates the identical stream). Size sequence uses the same
        fold_in/uniform-pick scheme as the default i.i.d. stream.
        """
        sizes = tuple(sizes)
        _validate_stream(n_requests, sizes)
        if not 0.0 <= corr < 1.0:
            raise ValueError(f"corr must be in [0, 1), got {corr}")
        innov = float(jnp.sqrt(1.0 - corr * corr))

        # per-(N, K) persistent population: (pl_shadow_db, c, x, y)
        state: dict[tuple[int, int], tuple] = {}
        out = []
        for i in range(n_requests):
            k_size, k_step = jax.random.split(jax.random.fold_in(key, i))
            n, k = sizes[int(jax.random.randint(k_size, (), 0, len(sizes)))]
            if (n, k) not in state:
                k_pos, k_shadow, k_fade, k_c = jax.random.split(k_step, 4)
                pls = _large_scale_db(k_pos, k_shadow, n, radius_m, shadowing_db)
                c = jax.random.uniform(k_c, (n,), minval=c_lo, maxval=c_hi)
                x, y = jax.random.normal(k_fade, (2, n, k))
            else:
                pls, c, x, y = state[(n, k)]
                ex, ey = jax.random.normal(k_step, (2, n, k))
                x = corr * x + innov * ex
                y = corr * y + innov * ey
            state[(n, k)] = (pls, c, x, y)
            gain_lin = 10.0 ** (-pls[:, None] / 10.0) * _envelope_gain(x, y)
            out.append(
                _assemble(
                    gain_lin, c, N=n, K=k, B=bbar * k, d_samples=d_samples,
                    D_bits=D_bits, C_round_bits=C_round_bits, L_rounds=L_rounds,
                    p_max_dbm=p_max_dbm, f_max_hz=f_max_hz, t_sc_max=t_sc_max,
                    q=q, eta=eta,
                )
            )
        return out


def _large_scale_db(
    k_pos: jax.Array, k_shadow: jax.Array, N: int, radius_m: float, shadowing_db: float
) -> jax.Array:
    """Path loss + shadowing in dB, same law as `iid_rayleigh`."""
    u = jax.random.uniform(k_pos, (N,), minval=1e-3)
    dist_km = jnp.sqrt(u) * radius_m / 1000.0
    pl_db = 128.1 + 37.6 * jnp.log10(dist_km)
    return pl_db + shadowing_db * jax.random.normal(k_shadow, (N,))


def _assemble(
    gain_lin, c, *, N, K, B, d_samples, D_bits, C_round_bits, L_rounds,
    p_max_dbm, f_max_hz, t_sc_max, q, eta,
) -> SystemParams:
    ones = jnp.ones((N,), jnp.float32)
    return SystemParams(
        g=gain_lin.astype(jnp.float32),
        c=c.astype(jnp.float32),
        d=d_samples * ones,
        D=D_bits * ones,
        C=(C_round_bits * L_rounds) * ones,
        p_max=dbm_to_watt(p_max_dbm) * ones,
        f_max=f_max_hz * ones,
        t_sc_max=t_sc_max * ones,
        N=N,
        K=K,
        B=B,
        q=q,
        eta=eta,
    )


FAMILY = register(GaussMarkov())
