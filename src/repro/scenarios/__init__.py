"""Scenario registry: named, seedable channel/population families.

Importing this package registers the four built-in families; resolve one
with `get_family(name)` (the `--scenario` flag, `FLConfig.scenario`, and the
benchmark sweep helper all route through it). See `base.py` for the
`ScenarioFamily` contract and the correctness gates every family must pass.
"""
from .base import (
    DEFAULT_STREAM_BBAR,
    DEFAULT_STREAM_SIZES,
    ScenarioFamily,
    get_family,
    list_families,
    register,
    table1_population,
)
from . import iid_rayleigh as _iid_rayleigh  # noqa: F401  (registers)
from . import ris_geometry as _ris_geometry  # noqa: F401
from . import gauss_markov as _gauss_markov  # noqa: F401
from . import hetero_classes as _hetero_classes  # noqa: F401
from .hetero_classes import DeviceClass, build_classes

__all__ = [
    "DEFAULT_STREAM_BBAR",
    "DEFAULT_STREAM_SIZES",
    "DeviceClass",
    "ScenarioFamily",
    "build_classes",
    "get_family",
    "list_families",
    "register",
    "table1_population",
]
