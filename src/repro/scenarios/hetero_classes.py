"""Heterogeneous device-class population family.

`iid_rayleigh` draws every device from one homogeneous population (uniform
cycle counts, shared ``f_max``/``p_max``). Real federated fleets are tiered:
phones, laptops, edge boxes. This family builds device classes from the
architecture registry (`repro.configs.registry`) — each registered arch's
analytic ``active_param_count()`` sets its class's relative per-sample
compute — and draws each device's class uniformly, giving it that class's
``c`` (cycles/sample, with +/-10% within-class jitter), ``f_max`` (CPU tier),
and ``p_max`` (radio tier).

Cycle counts are normalised so the smallest class lands at the paper's
Table-I floor (1e4 cycles/sample) and scale with the cube root of the
active-parameter ratio — absolute LM parameter counts (1e9+) would make
every deadline infeasible; what matters for the allocator is the *spread*:
slow-CPU/large-model devices force the assignment and frequency steps to
trade off against radio-rich ones. The channel itself stays the Section-V
i.i.d. Rayleigh law, so any objective difference vs `iid_rayleigh` is
attributable to population heterogeneity alone.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.core.types import SystemParams, dbm_to_watt

from .base import ScenarioFamily, register, table1_population


class DeviceClass(NamedTuple):
    """One device tier: representative arch + allocator-visible resources."""

    arch: str
    c_cycles: float      # cycles per sample (class centre, +/-10% jitter)
    f_max_hz: float      # CPU frequency ceiling
    p_max_dbm: float     # transmit power ceiling

    @property
    def p_max_w(self) -> float:
        return float(dbm_to_watt(self.p_max_dbm))


#: Table-I floor for the smallest class's cycles/sample
_C_FLOOR = 1e4
#: CPU and radio tiers, smallest model class first
_F_TIERS = (1.0e9, 2.0e9, 4.0e9)
_P_TIERS = (17.0, 20.0, 23.0)


def build_classes(n_classes: int = 3) -> tuple[DeviceClass, ...]:
    """Partition the registry's archs into ``n_classes`` size tiers.

    Archs are sorted by ``active_param_count()`` and split into contiguous
    groups; each group's median arch represents the class. ``c`` scales with
    the cube root of the active-parameter ratio to the smallest class,
    anchored at the Table-I floor.
    """
    if not 1 <= n_classes <= len(_F_TIERS):
        raise ValueError(f"n_classes must be in [1, {len(_F_TIERS)}], got {n_classes}")
    sized = sorted(
        ((get_config(a).active_param_count(), a) for a in list_archs()),
    )
    groups = [sized[(i * len(sized)) // n_classes : ((i + 1) * len(sized)) // n_classes]
              for i in range(n_classes)]
    reps = [g[len(g) // 2] for g in groups]
    base = reps[0][0]
    return tuple(
        DeviceClass(
            arch=arch,
            c_cycles=_C_FLOOR * float((count / base) ** (1.0 / 3.0)),
            f_max_hz=_F_TIERS[i],
            p_max_dbm=_P_TIERS[i],
        )
        for i, (count, arch) in enumerate(reps)
    )


class HeteroClasses(ScenarioFamily):
    name = "hetero_classes"

    def __init__(self, classes: tuple[DeviceClass, ...] | None = None):
        self._classes = classes

    @property
    def classes(self) -> tuple[DeviceClass, ...]:
        if self._classes is None:
            self._classes = build_classes()
        return self._classes

    def sample(
        self,
        key: jax.Array,
        *,
        N: int = 10,
        K: int = 50,
        B: float = 20e6,
        radius_m: float = 500.0,
        shadowing_db: float = 8.0,
        eta: int = 10,
        q: int = 2,
        **population,
    ) -> SystemParams:
        k_pos, k_shadow, k_fade, k_class, k_jit = jax.random.split(key, 5)

        # Section-V channel, unchanged from iid_rayleigh
        u = jax.random.uniform(k_pos, (N,), minval=1e-3)
        dist_km = jnp.sqrt(u) * radius_m / 1000.0
        pl_db = 128.1 + 37.6 * jnp.log10(dist_km)
        shadow = shadowing_db * jax.random.normal(k_shadow, (N,))
        ray = jax.random.exponential(k_fade, (N, K))
        gain_lin = 10.0 ** (-(pl_db + shadow)[:, None] / 10.0) * ray

        # per-device class draw + gather of the class resource columns
        classes = self.classes
        c_tab = jnp.asarray([cl.c_cycles for cl in classes], jnp.float32)
        f_tab = jnp.asarray([cl.f_max_hz for cl in classes], jnp.float32)
        p_tab = jnp.asarray([cl.p_max_w for cl in classes], jnp.float32)
        idx = jax.random.randint(k_class, (N,), 0, len(classes))
        jitter = jax.random.uniform(k_jit, (N,), minval=0.9, maxval=1.1)

        pop = table1_population(N, **population)
        pop["p_max"] = p_tab[idx]
        pop["f_max"] = f_tab[idx]
        return SystemParams(
            g=gain_lin.astype(jnp.float32),
            c=(c_tab[idx] * jitter).astype(jnp.float32),
            **pop,
            N=N,
            K=K,
            B=B,
            q=q,
            eta=eta,
        )


FAMILY = register(HeteroClasses())
