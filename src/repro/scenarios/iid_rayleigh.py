"""The paper's Section-V scenario: i.i.d. Rayleigh block fading.

Path loss 128.1 + 37.6 log10(dist_km) dB with 8 dB log-normal shadowing,
devices uniform in a 500 m disc, N0 = -174 dBm/Hz, B = 20 MHz, K = 50.

This is the original `repro.core.channel.sample_params` relocated behind the
registry — the random ops and key splits are unchanged, so draws are
bit-identical to the pre-registry sampler (the FL driver's plan==sequential
regression depends on that).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SystemParams, dbm_to_watt

from .base import ScenarioFamily, register


class IidRayleigh(ScenarioFamily):
    name = "iid_rayleigh"

    def sample(
        self,
        key: jax.Array,
        *,
        N: int = 10,
        K: int = 50,
        B: float = 20e6,
        radius_m: float = 500.0,
        shadowing_db: float = 8.0,
        p_max_dbm: float = 20.0,
        f_max_hz: float = 2e9,
        eta: int = 10,
        d_samples: float = 500.0,
        c_lo: float = 1e4,
        c_hi: float = 3e4,
        D_bits: float = 2.81e4,
        C_round_bits: float = 4.15e6,
        L_rounds: int = 10,
        t_sc_max: float = 20.0,
        q: int = 2,
    ) -> SystemParams:
        """Draw one scenario with the paper's Table-I defaults."""
        k_pos, k_shadow, k_fade, k_c = jax.random.split(key, 4)

        # uniform in a disc => r ~ sqrt(U) * radius
        u = jax.random.uniform(k_pos, (N,), minval=1e-3)
        dist_km = jnp.sqrt(u) * radius_m / 1000.0
        pl_db = 128.1 + 37.6 * jnp.log10(dist_km)
        shadow = shadowing_db * jax.random.normal(k_shadow, (N,))
        # small-scale Rayleigh fading per subcarrier (block fading in slot t)
        ray = jax.random.exponential(k_fade, (N, K))
        gain_lin = 10.0 ** (-(pl_db + shadow)[:, None] / 10.0) * ray

        c = jax.random.uniform(k_c, (N,), minval=c_lo, maxval=c_hi)

        ones = jnp.ones((N,), jnp.float32)
        return SystemParams(
            g=gain_lin.astype(jnp.float32),
            c=c.astype(jnp.float32),
            d=d_samples * ones,
            D=D_bits * ones,
            C=(C_round_bits * L_rounds) * ones,
            p_max=dbm_to_watt(p_max_dbm) * ones,
            f_max=f_max_hz * ones,
            t_sc_max=t_sc_max * ones,
            N=N,
            K=K,
            B=B,
            q=q,
            eta=eta,
        )


FAMILY = register(IidRayleigh())
