"""`ScenarioFamily` protocol + the string-keyed scenario registry.

Every layer that needs a wireless scenario — `solve_batch` sweeps, the FL
driver, the serving load generator, the benchmark figures — draws it through
one of the registered families instead of hand-rolling a sampler:

    from repro.scenarios import get_family
    fam = get_family("iid_rayleigh")
    params   = fam.sample(key, N=10, K=50)          # one SystemParams
    batch    = fam.sample_batch(key, 16, N=4, K=12)  # stacked (B, N, K)
    requests = fam.stream(key, 64, sizes=((3, 8), (4, 12)))  # serving stream

A family is **named** (its registry key), **seedable** (every draw is a pure
function of the JAX PRNG key), and produces three shapes of output:

* ``sample``       — one exact-shape `SystemParams`;
* ``sample_batch`` — ``batch`` i.i.d. draws stacked on a leading axis
  (feeds `repro.core.solve_batch` directly; default implementation vmaps
  ``sample`` over split keys, so batch == stacked singles by construction);
* ``stream``       — a list of mixed-size requests for the serving layer,
  all sharing one per-subcarrier bandwidth ``bbar`` so different sizes
  co-batch in one `ShapeBucket` (`pad_params` preserves ``bbar`` exactly).
  The default stream redraws i.i.d. per request; stateful families (e.g.
  ``gauss_markov``) override it with time-correlated traces.

Correctness gate (asserted in `tests/test_scenarios.py` for every registered
family): the allocator stays feasible and beats all paper baselines on the
family's draws, matches the exhaustive oracle on small (N, K), and padded-
bucket solves return the identical hardened assignment as exact-shape solves.
Diversity never outruns correctness.
"""
from __future__ import annotations

from typing import Iterable

import jax
import jax.numpy as jnp

from repro.core.types import SystemParams, dbm_to_watt

#: default mixed-size serving stream (matches the pre-registry
#: `sample_request_stream` defaults, so existing call sites are unchanged)
DEFAULT_STREAM_SIZES = ((3, 8), (4, 12), (6, 16))
#: default per-subcarrier bandwidth of a stream: the Table-I B/K
DEFAULT_STREAM_BBAR = 20e6 / 50


def table1_population(
    N: int,
    *,
    d_samples: float = 500.0,
    D_bits: float = 2.81e4,
    C_round_bits: float = 4.15e6,
    L_rounds: int = 10,
    t_sc_max: float = 20.0,
    p_max_dbm: float = 20.0,
    f_max_hz: float = 2e9,
) -> dict:
    """The paper's Table-I homogeneous device population as `SystemParams`
    keyword arrays (everything but the channel gain ``g`` and cycles ``c``).

    Families with richer populations (``hetero_classes``) replace individual
    entries; the rest share this single definition instead of each sampler
    re-plumbing the same seven kwargs.
    """
    ones = jnp.ones((N,), jnp.float32)
    return dict(
        d=d_samples * ones,
        D=D_bits * ones,
        C=(C_round_bits * L_rounds) * ones,
        p_max=dbm_to_watt(p_max_dbm) * ones,
        f_max=f_max_hz * ones,
        t_sc_max=t_sc_max * ones,
    )


class ScenarioFamily:
    """Base class for registered scenario generators (module docstring).

    Subclasses set ``name`` and implement ``sample``; ``sample_batch`` and
    ``stream`` have law-preserving defaults built on it.
    """

    #: registry key; subclasses must override
    name: str = ""

    def sample(self, key: jax.Array, *, N: int = 10, K: int = 50, **kwargs) -> SystemParams:
        """Draw one exact-shape scenario. Pure in ``key``."""
        raise NotImplementedError

    def sample_batch(self, key: jax.Array, batch: int, **kwargs) -> SystemParams:
        """Draw ``batch`` i.i.d. scenarios stacked on a leading axis.

        Defined as ``vmap(sample)`` over ``jax.random.split(key, batch)``, so
        ``tree_index(sample_batch(key, B), i) == sample(split(key, B)[i])``
        — the batch==stacked-singles equivalence every family is tested on.
        """
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        keys = jax.random.split(key, batch)
        return jax.vmap(lambda k: self.sample(k, **kwargs))(keys)

    def stream(
        self,
        key: jax.Array,
        n_requests: int,
        *,
        sizes: Iterable[tuple[int, int]] = DEFAULT_STREAM_SIZES,
        bbar: float = DEFAULT_STREAM_BBAR,
        **kwargs,
    ) -> list[SystemParams]:
        """Draw a mixed-size request stream for the serving layer.

        Each request picks a uniform (N, K) from ``sizes`` and shares the
        same per-subcarrier bandwidth ``bbar`` (total B = bbar * K scales
        with K) so different sizes pad into one `ShapeBucket` and co-batch.
        The default is i.i.d. per request; stateful families override.
        """
        sizes = tuple(sizes)
        _validate_stream(n_requests, sizes)
        out = []
        for i in range(n_requests):
            k_size, k_params = jax.random.split(jax.random.fold_in(key, i))
            n, k = sizes[int(jax.random.randint(k_size, (), 0, len(sizes)))]
            out.append(self.sample(k_params, N=n, K=k, B=bbar * k, **kwargs))
        return out


def _validate_stream(n_requests: int, sizes: tuple) -> None:
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if not sizes:
        raise ValueError("stream needs at least one (N, K) size")
    for n, k in sizes:
        if k < n:
            raise ValueError(
                f"stream size (N={n}, K={k}) violates K >= N (SystemParams contract)"
            )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FAMILIES: dict[str, ScenarioFamily] = {}


def register(family: ScenarioFamily) -> ScenarioFamily:
    """Register a family instance under ``family.name`` (unique)."""
    if not family.name:
        raise ValueError(f"{type(family).__name__} has no name; set .name")
    if family.name in _FAMILIES:
        raise ValueError(f"scenario family {family.name!r} already registered")
    _FAMILIES[family.name] = family
    return family


def get_family(name: str) -> ScenarioFamily:
    """Resolve a registered family by name (the `--scenario` flag's lookup)."""
    try:
        return _FAMILIES[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario family {name!r}; registered: {list_families()}"
        ) from None


def list_families() -> tuple[str, ...]:
    return tuple(sorted(_FAMILIES))
