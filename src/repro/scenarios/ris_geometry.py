"""RIS-assisted geometric channel family (Federated-Edge-AI-for-6G setup).

Large-scale gain is built from explicit Cartesian geometry instead of a
distance->dB curve: a BS at (-50, 0, 10) m, a RIS at (0, 0, 10) m with
``n_ris_ele`` elements of side ``lambda/10``, and users uniform on a ground
disc around the RIS. Per-user gain is the sum of

* the direct BS->user path, ``G_bs * G_user * (lambda / 4 pi d)^alpha``
  with ``alpha_direct`` typically > 2 (blocked/NLoS), and
* the RIS cascade, ``G_bs * G_ris * G_user *
  (n_ris * A_ele * lambda / 4 pi)^2 / (d_bs_ris * d_ris_user)^2`` — the
  standard far-field product-distance scaling for a reflect-array of
  aperture ``n_ris * A_ele``.

Small-scale Rayleigh fading stays i.i.d. per subcarrier (block fading), so
only the large-scale law differs from `iid_rayleigh`: users near the RIS see
the cascade dominate, cell-edge users fall back to the weak direct path —
exactly the gain spread the allocator's assignment step has to arbitrate.
Device population is the paper's Table-I (`table1_population`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SystemParams

from .base import ScenarioFamily, register, table1_population

#: speed of light, m/s
_C0 = 3e8


class RisGeometry(ScenarioFamily):
    name = "ris_geometry"

    def sample(
        self,
        key: jax.Array,
        *,
        N: int = 10,
        K: int = 50,
        B: float = 20e6,
        radius_m: float = 100.0,
        fc_hz: float = 915e6,
        alpha_direct: float = 3.5,
        n_ris_ele: int = 16,
        bs_gain_db: float = 5.0,
        ris_gain_db: float = 5.0,
        user_gain_db: float = 0.0,
        bs_xyz: tuple[float, float, float] = (-50.0, 0.0, 10.0),
        ris_xyz: tuple[float, float, float] = (0.0, 0.0, 10.0),
        eta: int = 10,
        c_lo: float = 1e4,
        c_hi: float = 3e4,
        q: int = 2,
        **population,
    ) -> SystemParams:
        k_pos, k_fade, k_c = jax.random.split(key, 3)

        lam = _C0 / fc_hz
        g_bs = 10.0 ** (bs_gain_db / 10.0)
        g_ris = 10.0 ** (ris_gain_db / 10.0)
        g_user = 10.0 ** (user_gain_db / 10.0)
        bs = jnp.asarray(bs_xyz)
        ris = jnp.asarray(ris_xyz)

        # users uniform on the ground disc centred under the RIS
        u, theta = jnp.split(jax.random.uniform(k_pos, (2 * N,)), 2)
        r = jnp.sqrt(jnp.maximum(u, 1e-6)) * radius_m
        users = jnp.stack(
            [ris[0] + r * jnp.cos(2 * jnp.pi * theta),
             ris[1] + r * jnp.sin(2 * jnp.pi * theta),
             jnp.zeros((N,))],
            axis=-1,
        )

        d_direct = jnp.linalg.norm(users - bs, axis=-1)
        d_bs_ris = jnp.linalg.norm(ris - bs)
        d_ris_user = jnp.linalg.norm(users - ris, axis=-1)

        direct = g_bs * g_user * (lam / (4.0 * jnp.pi * d_direct)) ** alpha_direct
        aperture = n_ris_ele * (lam / 10.0) ** 2  # element side = lambda/10
        cascade = (
            g_bs * g_ris * g_user
            * (aperture / lam) ** 2
            / (4.0 * jnp.pi * d_bs_ris * d_ris_user) ** 2
        )
        large_scale = direct + cascade

        # small-scale Rayleigh per subcarrier, as in iid_rayleigh
        ray = jax.random.exponential(k_fade, (N, K))
        gain_lin = large_scale[:, None] * ray

        c = jax.random.uniform(k_c, (N,), minval=c_lo, maxval=c_hi)

        return SystemParams(
            g=gain_lin.astype(jnp.float32),
            c=c.astype(jnp.float32),
            **table1_population(N, **population),
            N=N,
            K=K,
            B=B,
            q=q,
            eta=eta,
        )


FAMILY = register(RisGeometry())
