"""Mamba (S6) selective-state-space block, as interleaved in Jamba.

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t        (per channel, state N)
    y_t = C_t h_t + D x_t

Prefill runs a sequential lax.scan with carry (B, d_inner, N) — h is never
materialised across time (a (B,S,d_inner,N) tensor would be terabytes at
Jamba scale); the Pallas `mamba_scan` kernel is the TPU chunked path and this
is its oracle. Decode carries (conv window, h).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init


def _dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba_params(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    R = _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, R + 2 * N), dtype=dtype),
        "dt_proj": dense_init(ks[3], (R, di), dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(0.01)) * jnp.ones((di,), jnp.float32),
        "A_log": jnp.log(A),                    # (di, N) fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _causal_conv(x, w, b, carry=None):
    """Depthwise causal conv. x: (B,S,di); w: (K,di); carry: (B,K-1,di)."""
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return out + b, xp[:, -(K - 1):]


def _ssm_inputs(p, cfg, xz):
    """Shared pre-scan computation. Returns (x_conv, z, dt, B, C)."""
    di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    R = _dt_rank(cfg)
    x, z = xz[..., :di], xz[..., di:]
    proj = jnp.einsum("bsd,dr->bsr", x, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", proj[..., :R], p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )                                                        # (B,S,di) fp32
    Bmat = proj[..., R : R + N].astype(jnp.float32)          # (B,S,N)
    Cmat = proj[..., R + N :].astype(jnp.float32)
    return x, z, dt, Bmat, Cmat


def mamba_forward(p, cfg, x_in, state):
    """x_in: (B,S,d); state {"conv": (B,K-1,di), "h": (B,di,N)}."""
    xz = jnp.einsum("bsd,de->bse", x_in, p["in_proj"])
    di = cfg.ssm_expand * cfg.d_model
    x, z = xz[..., :di], xz[..., di:]
    x, conv_carry = _causal_conv(x, p["conv_w"], p["conv_b"], state["conv"])
    x = jax.nn.silu(x)
    _, _, dt, Bm, Cm = _ssm_inputs(p, cfg, jnp.concatenate([x, z], -1))

    A = -jnp.exp(p["A_log"])                                 # (di,N)
    io_dt = jnp.bfloat16 if getattr(cfg, "ssm_io_bf16", False) else jnp.float32
    xf = x.astype(io_dt)

    def step(h, inp):
        # inputs may stream in bf16 (cfg.ssm_io_bf16); math stays fp32
        x_t, dt_t, B_t, C_t = (t.astype(jnp.float32) for t in inp)
        da = jnp.exp(dt_t[..., None] * A)                    # (B,di,N)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    seq = (xf.swapaxes(0, 1), dt.astype(io_dt).swapaxes(0, 1),
           Bm.astype(io_dt).swapaxes(0, 1), Cm.astype(io_dt).swapaxes(0, 1))
    unroll = min(getattr(cfg, "scan_unroll", 1), x.shape[1])
    h_new, ys = jax.lax.scan(step, state["h"], seq, unroll=unroll)
    y = ys.swapaxes(0, 1) + xf * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, {"conv": conv_carry, "h": h_new}


def init_mamba_state(cfg, batch, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }
