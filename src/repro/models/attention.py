"""Attention: GQA (+RoPE, sliding window, softcap, bias) and DeepSeek MLA.

Prefill/train uses a chunked flash-style attention in pure jnp (running
log-sum-exp over KV chunks — O(S * chunk) memory instead of O(S^2)); it is
also the oracle for the Pallas flash kernel (`repro.kernels.flash_attention`).
Decode attends one query over a KV cache; sliding-window layers keep a ring
buffer of size `window` with explicit kv-position tags, so long_500k local
layers cache O(window), not O(S) (DESIGN.md §5).

MLA (DeepSeek-V3): low-rank q and kv projections with a decoupled RoPE head.
The cache stores only (c_kv, k_rope) — ~(kv_lora + rope_dim) per token instead
of 2*H*hd. Decode uses the absorbed formulation (scores straight from the
latent without materialising per-head K/V).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init, rms_norm, softcap


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, KV, hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, KV, hd), dtype=dtype),
        "wo": dense_init(ks[3], (H, hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def init_mla_params(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    qlr, kvlr, rd = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], (d, qlr), dtype=dtype),
        "q_norm": jnp.zeros((qlr,), dtype),
        "w_uq": dense_init(ks[1], (qlr, H, hd + rd), dtype=dtype),
        "w_dkv": dense_init(ks[2], (d, kvlr + rd), dtype=dtype),
        "kv_norm": jnp.zeros((kvlr,), dtype),
        "w_uk": dense_init(ks[3], (kvlr, H, hd), dtype=dtype),
        "w_uv": dense_init(ks[4], (kvlr, H, hd), dtype=dtype),
        "wo": dense_init(ks[5], (H, hd, d), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# chunked flash attention (jnp oracle / CPU path)
# ---------------------------------------------------------------------------

def flash_attention(
    q, k, v, *,
    q_positions, kv_positions,
    causal: bool = True,
    window: int | None = None,
    cap: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
):
    """q: (B,S,H,hd); k/v: (B,Skv,KV,hd) with H = G*KV. Returns (B,S,H,hd).

    kv_positions < 0 marks invalid (unwritten ring-buffer) entries.
    """
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qp = -(-S // q_chunk) * q_chunk
    kp = -(-Skv // kv_chunk) * kv_chunk
    qpad, kpad = qp - S, kp - Skv
    q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_positions, (0, qpad), constant_values=2**30)
    kv_pos = jnp.pad(kv_positions, (0, kpad), constant_values=-1)

    q = q.reshape(B, qp // q_chunk, q_chunk, KV, G, hd)
    k = k.reshape(B, kp // kv_chunk, kv_chunk, KV, hd)
    v = v.reshape(B, kp // kv_chunk, kv_chunk, KV, hd)
    q_pos = q_pos.reshape(qp // q_chunk, q_chunk)
    kv_pos = kv_pos.reshape(kp // kv_chunk, kv_chunk)

    @jax.checkpoint  # don't save per-chunk p-matrices for backward (§Perf)
    def q_step_body(qc_in):
        qc, qpos_c = qc_in  # (B, qc, KV, G, hd), (qc,)

        def kv_step(carry, kc_in):
            out, m, l = carry
            kc, vc, kpos_c = kc_in
            s = jnp.einsum(
                "bqkgh,bskh->bkgqs", qc.astype(jnp.float32), kc.astype(jnp.float32)
            ) * scale
            if cap is not None:
                s = softcap(s, cap)
            mask = kpos_c[None, :] >= 0
            if causal:
                mask &= kpos_c[None, :] <= qpos_c[:, None]
            if window is not None:
                mask &= qpos_c[:, None] - kpos_c[None, :] < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
            out = out * corr[..., None] + pv
            return (out, m_new, l), None

        out0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, q_chunk), -jnp.inf)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        (out, m, l), _ = jax.lax.scan(
            kv_step, (out0, m0, l0),
            (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos),
        )
        out = out / jnp.maximum(l[..., None], 1e-20)
        # cast before stacking: the scan output buffer is S-sized
        return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B, qc, KV, G, hd)

    def q_step(_, qc_in):
        return None, q_step_body(qc_in)

    _, outs = jax.lax.scan(q_step, None, (q.swapaxes(0, 1), q_pos))
    return outs.swapaxes(0, 1).reshape(B, qp, H, hd)[:, :S]


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def _project_qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, cfg, x, positions, *, window=None, use_kernel=False):
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    if use_kernel:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(
            q, k, v, causal=cfg.causal, window=window, cap=cfg.attn_softcap
        )
    else:
        out = flash_attention(
            q, k, v,
            q_positions=positions, kv_positions=positions,
            causal=cfg.causal, window=window, cap=cfg.attn_softcap,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_kv_cache(cfg, batch, length, window, dtype):
    size = min(length, window) if window else length
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
        "pos_tag": jnp.full((size,), -1, jnp.int32),
    }


def gqa_decode(p, cfg, x, pos, cache, *, window=None):
    """One-token decode. x: (B,1,d); pos: scalar int32. Updates ring cache."""
    positions = pos[None].astype(jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    size = cache["k"].shape[1]
    slot = (pos % size).astype(jnp.int32)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1),
        "pos_tag": jax.lax.dynamic_update_slice_in_dim(
            cache["pos_tag"], positions, slot, axis=0
        ),
    }
    kc, vc, tags = cache["k"], cache["v"], cache["pos_tag"]
    B, S, KV, hd = kc.shape
    H = cfg.n_heads
    G = H // KV
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, kc.astype(jnp.float32)) / jnp.sqrt(hd)
    if cfg.attn_softcap:
        s = softcap(s, cfg.attn_softcap)
    mask = (tags >= 0) & (tags <= pos)
    if window is not None:
        mask &= pos - tags < window
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, vc.astype(jnp.float32))
    out = out.reshape(B, 1, H, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def _mla_q(p, cfg, x, positions):
    ql = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"])
    q_nope, q_rope = q[..., : cfg.hd], q[..., cfg.hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., cfg.kv_lora_rank:][:, :, None, :]          # 1 shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(p, cfg, x, positions):
    """Train/prefill: materialise per-head K/V from the latent, flash over it."""
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (H, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, cfg.qk_rope_dim)))
    out = flash_attention(
        q, k, v_pad, q_positions=positions, kv_positions=positions, causal=True,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
    )[..., : cfg.hd]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_mla_cache(cfg, batch, length, dtype):
    return {
        "c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, length, cfg.qk_rope_dim), dtype),
        "pos_tag": jnp.full((length,), -1, jnp.int32),
    }


def mla_decode(p, cfg, x, pos, cache):
    """Absorbed decode: score/accumulate in the latent space (no per-head K/V)."""
    positions = pos[None].astype(jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)     # (B,1,H,hd), (B,1,H,rd)
    c_kv_t, k_rope_t = _mla_latent(p, cfg, x, positions)
    slot = pos.astype(jnp.int32)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_t, slot, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_t, slot, 1),
        "pos_tag": jax.lax.dynamic_update_slice_in_dim(
            cache["pos_tag"], positions, slot, 0
        ),
    }
    c_kv, k_rope, tags = cache["c_kv"], cache["k_rope"], cache["pos_tag"]
    # absorb: q_eff = q_nope @ w_uk  -> latent space
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))            # (B,1,H,r)
    s = jnp.einsum("bshr,btr->bhst", q_eff, c_kv.astype(jnp.float32))
    s = s + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                       k_rope.astype(jnp.float32))
    s = s / jnp.sqrt(cfg.hd + cfg.qk_rope_dim)
    mask = (tags >= 0) & (tags <= pos)
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    lat = jnp.einsum("bhst,btr->bshr", w, c_kv.astype(jnp.float32))  # (B,1,H,r)
    out = jnp.einsum("bshr,rhk->bshk", lat, p["w_uv"].astype(jnp.float32))
    out = out.astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache
