"""Model assembly: stages of lax.scan'ed layer periods + train/prefill/decode.

A model = embed (or stub-frontend projection) -> stages -> final norm -> head.
Each stage scans over `n_periods` stacked copies of its `block_pattern`
(DESIGN.md §6); block kinds: attn | attn_local | mamba | rwkv. FFN per layer
is dense or MoE (statically known per pattern position; requires
pattern_len % moe_every == 0).

Three entry points, all pure and jit/pjit-able:
  * loss_fn(params, batch, key)                -> scalar   (training)
  * prefill(params, tokens/embeds)             -> (logits, cache)
  * decode_step(params, token, pos, cache)     -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mam
from . import moe as moe_mod
from . import rwkv as rwk
from .config import ModelConfig
from .layers import cross_entropy, dense_init, rms_norm, softcap


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, is_moe: bool):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln": jnp.zeros((d,), dt)}
    if kind.startswith("attn"):
        p["attn"] = (
            attn.init_mla_params(ks[0], cfg, dt)
            if cfg.use_mla
            else attn.init_attn_params(ks[0], cfg, dt)
        )
    elif kind == "mamba":
        p["mamba"] = mam.init_mamba_params(ks[0], cfg, dt)
    elif kind == "rwkv":
        p["rwkv"] = rwk.init_rwkv_params(ks[0], cfg, dt)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        p["post_ln"] = jnp.zeros((d,), dt)
    p["ffn_ln"] = jnp.zeros((d,), dt)
    if kind != "rwkv":  # rwkv channel-mix lives in its own params
        p["ffn"] = (
            moe_mod.init_moe_params(ks[1], cfg, dt)
            if is_moe
            else moe_mod.init_dense_ffn(ks[1], cfg, dt)
        )
        if cfg.post_norm:
            p["post_ffn_ln"] = jnp.zeros((d,), dt)
    return p


def _stage_layout(cfg: ModelConfig):
    """[(stage_name, n_periods, [(kind, is_moe) per pattern pos])]."""
    out = []
    offset = 0
    for name, n_periods, moe_on in cfg.stages():
        pat = []
        for j, kind in enumerate(cfg.block_pattern):
            is_moe = moe_on and cfg.is_moe_layer(offset + j) and kind != "rwkv"
            pat.append((kind, is_moe))
        if cfg.n_experts and moe_on:
            # static pattern requires alignment of moe_every with pattern
            assert cfg.pattern_len % cfg.moe_every == 0 or cfg.moe_every == 1
        out.append((name, n_periods, pat))
        offset += n_periods * cfg.pattern_len
    return out


def init_params(key: jax.Array, cfg: ModelConfig):
    dt = _dtype(cfg)
    d = cfg.d_model
    k_embed, k_head, k_front, k_stages = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": dense_init(k_embed, (cfg.vocab, d), scale=0.02, dtype=dt),
        "final_ln": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        out_dim = cfg.n_classes if cfg.arch_type == "audio" else cfg.vocab
        params["head"] = dense_init(k_head, (d, out_dim), dtype=dt)
    if cfg.frontend is not None:
        params["frontend_proj"] = dense_init(k_front, (cfg.frontend_dim, d), dtype=dt)

    stages = {}
    for si, (name, n_periods, pat) in enumerate(_stage_layout(cfg)):
        k_stage = jax.random.fold_in(k_stages, si)

        def init_period(k):
            kb = jax.random.split(k, len(pat))
            return {
                f"b{j}": _init_block(kb[j], cfg, kind, is_moe)
                for j, (kind, is_moe) in enumerate(pat)
            }

        stages[name] = jax.vmap(init_period)(jax.random.split(k_stage, n_periods))
    params["stages"] = stages
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _block_window(cfg, kind):
    return cfg.sliding_window if kind == "attn_local" else None


def _apply_block(p, cfg, kind, is_moe, x, positions, mesh, use_kernel):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if kind.startswith("attn"):
        if cfg.use_mla:
            inner = attn.mla_forward(p["attn"], cfg, h, positions)
        else:
            inner = attn.gqa_forward(
                p["attn"], cfg, h, positions,
                window=_block_window(cfg, kind), use_kernel=use_kernel,
            )
        aux = 0.0
    elif kind == "mamba":
        st = mam.init_mamba_state(cfg, x.shape[0], x.dtype)
        inner, _ = mam.mamba_forward(p["mamba"], cfg, h, st)
        aux = 0.0
    else:  # rwkv
        st = rwk.init_rwkv_state(cfg, x.shape[0], x.dtype)
        inner, _ = rwk.time_mix(p["rwkv"], cfg, h, st)
        aux = 0.0
    if cfg.post_norm:
        inner = rms_norm(inner, p["post_ln"], cfg.norm_eps)
    x = x + inner

    if kind == "rwkv":
        st = rwk.init_rwkv_state(cfg, x.shape[0], x.dtype)
        h = rms_norm(x, p["ffn_ln"], cfg.norm_eps)
        out, _ = rwk.channel_mix(p["rwkv"], cfg, h, st)
        return x + out, aux

    h = rms_norm(x, p["ffn_ln"], cfg.norm_eps)
    if is_moe:
        out, moe_aux = moe_mod.moe_ffn(p["ffn"], cfg, h, mesh=mesh)
        aux = aux + moe_aux
    else:
        out = moe_mod.dense_ffn(p["ffn"], cfg, h)
    if cfg.post_norm:
        out = rms_norm(out, p["post_ffn_ln"], cfg.norm_eps)
    return x + out, aux


def _constrain_residual(x, mesh):
    """Keep the (B, S, d) residual stream replicated over 'model': embed
    output is d-sharded, and without the hint GSPMD re-gathers 1 GB f32
    activations around every block (§Perf)."""
    if mesh is None or "model" not in mesh.axis_names:
        return x
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import constrain, data_axes

    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if (x.shape[0] % dp_size == 0 and dp) else None
    return constrain(x, mesh, P(bspec, None, None))


def _run_stages(params, cfg, x, positions, mesh, use_kernel, remat=True):
    total_aux = 0.0
    x = _constrain_residual(x, mesh)
    for name, n_periods, pat in _stage_layout(cfg):
        stage_params = params["stages"][name]

        def period_fn(x, p_period):
            aux = 0.0
            for j, (kind, is_moe) in enumerate(pat):
                x, a = _apply_block(
                    p_period[f"b{j}"], cfg, kind, is_moe, x, positions, mesh, use_kernel
                )
                aux = aux + a
            return _constrain_residual(x, mesh), aux

        if remat:
            period_fn = jax.checkpoint(period_fn)

        x, auxs = jax.lax.scan(lambda c, p: period_fn(c, p), x, stage_params)
        total_aux = total_aux + jnp.sum(auxs)
    return x, total_aux


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def _embed(params, cfg, batch):
    if cfg.frontend == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["frame_embeds"], params["frontend_proj"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        return x.astype(_dtype(cfg)), positions
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.frontend == "vision":
        pe = jnp.einsum("bpf,fd->bpd", batch["patch_embeds"], params["frontend_proj"])
        n_patch = pe.shape[1]
        x = jnp.concatenate([pe.astype(x.dtype), x[:, n_patch:]], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, positions


def _head(params, cfg, x, mesh=None):
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if mesh is not None and "model" in mesh.axis_names:
        # keep the (B, S, V) logits vocab-sharded — replicated 256k-vocab
        # logits would be tens of GiB per device (DESIGN.md §6)
        from repro.parallel.sharding import batch_spec, constrain
        from jax.sharding import PartitionSpec as P

        dp = tuple(batch_spec(mesh))[0] if logits.shape[0] % _dp_size(mesh) == 0 else None
        logits = constrain(logits, mesh, P(dp, None, "model"))
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


def _dp_size(mesh):
    n = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            n *= mesh.shape[a]
    return n


def forward(params, cfg: ModelConfig, batch, mesh=None, use_kernel=False, remat=True):
    x, positions = _embed(params, cfg, batch)
    x, aux = _run_stages(params, cfg, x, positions, mesh, use_kernel, remat)
    return _head(params, cfg, x, mesh=mesh), aux


def loss_fn(params, cfg: ModelConfig, batch, mesh=None, use_kernel=False, remat=True):
    logits, aux = forward(params, cfg, batch, mesh, use_kernel, remat)
    mask = batch.get("mask") if cfg.arch_type == "audio" else None
    if mesh is not None and "model" in mesh.axis_names and logits.shape[-1] % mesh.shape["model"] == 0:
        loss = _sharded_cross_entropy(logits, batch["labels"], mesh, mask)
    else:
        loss = cross_entropy(logits, batch["labels"], mask=mask)
    if cfg.n_experts:
        loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return loss


def _sharded_cross_entropy(logits, labels, mesh, mask=None):
    """CE with the vocab axis kept sharded end-to-end (shard_map + psum).

    The plain jnp CE on (dp, None, 'model')-sharded logits makes GSPMD
    all-gather AND all-reduce the full f32 (B, S, V) tensor (67 GB/device for
    gemma2's 256k vocab at train_4k) — measured in §Perf. Here each vocab
    shard reduces locally; only (B, S) statistics cross the link.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import batch_spec, data_axes

    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if (logits.shape[0] % dp_size == 0 and dp) else None

    v_local = logits.shape[-1] // mesh.shape["model"]
    # the max shift is numerical stability only; computed outside the
    # shard_map (pmax has no differentiation rule, even under stop_gradient
    # its jvp is traced)
    m_global = jax.lax.stop_gradient(jnp.max(logits.astype(jnp.float32), -1))

    def shard_fn(lg, lb, mk, m):
        lg = lg.astype(jnp.float32)
        shard = jax.lax.axis_index("model")
        sumexp = jax.lax.psum(jnp.sum(jnp.exp(lg - m[..., None]), -1), "model")
        logz = m + jnp.log(sumexp)
        local = lb - shard * v_local
        in_shard = (local >= 0) & (local < v_local)
        onehot = jax.nn.one_hot(jnp.where(in_shard, local, 0), v_local, dtype=lg.dtype)
        gold = jax.lax.psum(
            jnp.sum(lg * onehot, -1) * in_shard.astype(lg.dtype), "model"
        )
        nll = logz - gold
        valid = mk & (lb >= 0)
        nll = jnp.where(valid, nll, 0.0)
        return (
            jax.lax.psum(jnp.sum(nll), dp) if dp else jnp.sum(nll),
            jax.lax.psum(jnp.sum(valid), dp) if dp else jnp.sum(valid),
        )

    if mask is None:
        mask_in = jnp.ones(labels.shape, jnp.bool_)
    else:
        mask_in = mask
    total, count = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(
            P(bspec, None, "model"), P(bspec, None), P(bspec, None),
            P(bspec, None),
        ),
        out_specs=(P(), P()),
        check_rep=False,
    )(logits, labels, mask_in, m_global)
    return total / jnp.maximum(count, 1)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    caches = {}
    for name, n_periods, pat in _stage_layout(cfg):
        def one_period(_):
            c = {}
            for j, (kind, _m) in enumerate(pat):
                if kind.startswith("attn"):
                    if cfg.use_mla:
                        c[f"b{j}"] = attn.init_mla_cache(cfg, batch, max_len, dt)
                    else:
                        c[f"b{j}"] = attn.init_kv_cache(
                            cfg, batch, max_len, _block_window(cfg, kind), dt
                        )
                elif kind == "mamba":
                    c[f"b{j}"] = mam.init_mamba_state(cfg, batch, dt)
                else:
                    c[f"b{j}"] = rwk.init_rwkv_state(cfg, batch, dt)
            return c

        caches[name] = jax.vmap(one_period)(jnp.arange(n_periods))
    return caches


def _decode_block(p, c, cfg, kind, is_moe, x, pos, mesh):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if kind.startswith("attn"):
        if cfg.use_mla:
            inner, c = attn.mla_decode(p["attn"], cfg, h, pos, c)
        else:
            inner, c = attn.gqa_decode(
                p["attn"], cfg, h, pos, c, window=_block_window(cfg, kind)
            )
    elif kind == "mamba":
        inner, c = mam.mamba_forward(p["mamba"], cfg, h, c)
    else:
        inner, c_t = rwk.time_mix(p["rwkv"], cfg, h, c)
        c = {**c, **c_t}
    if cfg.post_norm:
        inner = rms_norm(inner, p["post_ln"], cfg.norm_eps)
    x = x + inner

    if kind == "rwkv":
        h = rms_norm(x, p["ffn_ln"], cfg.norm_eps)
        out, c2 = rwk.channel_mix(p["rwkv"], cfg, h, c)
        c = {**c, **c2}
        return x + out, c
    h = rms_norm(x, p["ffn_ln"], cfg.norm_eps)
    if is_moe:
        out, _ = moe_mod.moe_ffn(p["ffn"], cfg, h, mesh=mesh)
    else:
        out = moe_mod.dense_ffn(p["ffn"], cfg, h)
    if cfg.post_norm:
        out = rms_norm(out, p["post_ffn_ln"], cfg.norm_eps)
    return x + out, c


def decode_step(params, cfg: ModelConfig, token, pos, cache, mesh=None):
    """token: (B, 1) int32 (or (B,1,frontend) for audio); pos scalar int32."""
    cache = dict(cache)
    x = params["embed"][token]
    for name, n_periods, pat in _stage_layout(cfg):
        def period_fn(x, xs):
            p_period, c_period = xs
            new_c = {}
            for j, (kind, is_moe) in enumerate(pat):
                x, cj = _decode_block(
                    p_period[f"b{j}"], c_period[f"b{j}"], cfg, kind, is_moe,
                    x, pos, mesh,
                )
                new_c[f"b{j}"] = cj
            return x, new_c

        x, cache[name] = jax.lax.scan(
            period_fn, x, (params["stages"][name], cache[name])
        )
    logits = _head(params, cfg, x, mesh=mesh)
    return logits, cache


def prefill(params, cfg: ModelConfig, batch, mesh=None, use_kernel=False):
    """Full-sequence forward returning logits (cache build is exercised by the
    decode path; serving benchmarks measure prefill logits + decode steps)."""
    return forward(params, cfg, batch, mesh=mesh, use_kernel=use_kernel, remat=False)[0]
