"""ModelConfig: one dataclass describing every assigned architecture.

The layer stack is a cycle over `block_pattern` (e.g. gemma2 alternates
("attn_local", "attn"); jamba runs 7 mamba + 1 attn per period). Stacks are
lax.scan'ed over stacked per-period parameters so HLO size is O(pattern), not
O(depth) — required for 512-host-device CPU compiles (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- attention options ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None     # window for "attn_local" blocks
    attn_softcap: Optional[float] = None     # gemma2 attention logit softcap
    final_softcap: Optional[float] = None    # gemma2 final logit softcap
    causal: bool = True                      # False => encoder (hubert)
    # --- layer pattern (cycled) ---
    block_pattern: Tuple[str, ...] = ("attn",)   # attn | attn_local | mamba | rwkv
    # --- FFN / MoE ---
    ffn_kind: str = "swiglu"                 # swiglu | gelu
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                       # MoE FFN on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    n_dense_layers: int = 0                  # deepseek: dense FFN prefix
    moe_d_ff: Optional[int] = None           # expert hidden (deepseek: 2048)
    moe_dense_residual: bool = False         # arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    # --- SSM ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # --- frontends (stub: precomputed embeddings) ---
    frontend: Optional[str] = None           # None | "audio" | "vision"
    frontend_dim: int = 512                  # stub embedding dim before proj
    n_classes: int = 0                       # hubert prediction classes
    # --- misc ---
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    post_norm: bool = False                  # gemma2 pre+post block norms
    # --- perf knobs (§Perf hillclimbing) ---
    ssm_io_bf16: bool = False  # stream mamba scan inputs (x, dt, B, C) in
                               # bf16 (state & step math stay fp32)
    scan_unroll: int = 1      # unroll factor for mamba/rwkv time scans:
                              # unrolled steps fuse, cutting per-step HBM
                              # round-trips of the recurrent state
    moe_2d: bool = False      # serving: experts on 'model' x d_ff on 'data'
                              # (tokens replicated) instead of FSDP weight
                              # gathers — decode is weight-bound, tokens tiny
    attn_q_chunk: int = 512   # jnp flash-attention tile sizes
    attn_kv_chunk: int = 1024

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    def stages(self):
        """(pattern, n_periods, moe_enabled_flags) stacks; deepseek gets a
        dense prefix stage. Every stage length must be divisible by the
        pattern length."""
        out = []
        if self.n_dense_layers:
            assert self.n_dense_layers % self.pattern_len == 0
            out.append(("dense_prefix", self.n_dense_layers // self.pattern_len, False))
        rest = self.n_layers - self.n_dense_layers
        assert rest % self.pattern_len == 0, (
            f"{self.name}: {rest} layers not divisible by pattern {self.block_pattern}"
        )
        out.append(("main", rest // self.pattern_len, self.n_experts > 0))
        return out

    def is_moe_layer(self, global_idx: int) -> bool:
        if self.n_experts == 0 or global_idx < self.n_dense_layers:
            return False
        return (global_idx % self.moe_every) == self.moe_offset

    # ---------------- accounting (roofline §7) ----------------
    def param_count(self) -> float:
        """Analytic parameter count (embeddings + stacks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        for i in range(self.n_layers):
            kind = self.block_pattern[i % self.pattern_len]
            if kind.startswith("attn"):
                if self.use_mla:
                    qlr, kvlr, rd = self.q_lora_rank, self.kv_lora_rank, self.qk_rope_dim
                    n += d * qlr + qlr * H * (hd + rd)        # q down/up
                    n += d * (kvlr + rd) + kvlr * H * 2 * hd  # kv down/up
                    n += H * hd * d                           # o
                else:
                    n += d * H * hd + 2 * d * KV * hd + H * hd * d
            elif kind == "mamba":
                di = self.ssm_expand * d
                n += 2 * d * di + di * self.ssm_conv + di * (2 * self.ssm_state + 2) + di * d
            elif kind == "rwkv":
                n += 4 * d * d + d * d  # r,k,v,g + out
                n += 2 * d * 64         # decay lora
            # ffn (every layer has one: jamba puts MoE/MLP after mamba blocks
            # too; rwkv's channel-mix is its FFN)
            if kind == "rwkv":
                n += d * ff + ff * d + d * d
            elif self.is_moe_layer(i):
                eff = self.moe_d_ff or ff
                n += self.n_experts * 3 * d * eff
                n += self.n_shared_experts * 3 * d * eff
                n += d * self.n_experts  # router
                if self.moe_dense_residual:
                    n += 3 * d * ff
            else:
                n += (3 if self.ffn_kind in ("swiglu", "geglu") else 2) * d * ff
        return float(n)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        eff = self.moe_d_ff or ff
        inactive = 0.0
        for i in range(self.n_layers):
            if self.is_moe_layer(i):
                inactive += (self.n_experts - self.top_k) * 3 * d * eff
        return self.param_count() - float(inactive)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family: <=2 periods, d<=512, <=4 experts."""
    pat = cfg.pattern_len
    d = min(cfg.d_model, 256)
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, 2))
    return cfg.scaled(
        n_layers=2 * pat if cfg.n_dense_layers == 0 else 2 * pat + pat,
        n_dense_layers=pat if cfg.n_dense_layers else 0,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=d // heads,
        d_ff=min(cfg.d_ff, 512),
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.moe_d_ff else None,
        vocab=min(cfg.vocab, 512),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        q_lora_rank=min(cfg.q_lora_rank, 64) if cfg.q_lora_rank else 0,
        kv_lora_rank=min(cfg.kv_lora_rank, 32) if cfg.kv_lora_rank else 0,
        qk_rope_dim=min(cfg.qk_rope_dim, 16) if cfg.qk_rope_dim else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        frontend_dim=min(cfg.frontend_dim, 64),
        dtype="float32",
    )
