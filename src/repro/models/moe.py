"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Dispatch is a sort-based grouped matmul (tokens permuted into per-expert
capacity slots, one batched einsum over experts, weighted scatter-add back) —
no (T, E, C) one-hot tensors, so it scales to 256 experts at 1M tokens.

Expert parallelism: `moe_ffn` optionally runs under shard_map with experts
sharded on the `model` mesh axis; token activations arrive replicated across
`model` (they are sharded on `data` only), each device dispatches to its local
expert shard, and a psum over `model` combines contributions. Chosen over
all-to-all token routing because GSPMD cannot infer a good a2a schedule from
gather/scatter dispatch (DESIGN.md §4); an explicit a2a variant is a §Perf
hillclimb candidate.

Also here: dense FFN variants (swiglu / gelu+bias) and the shared-expert and
dense-residual paths (DeepSeek-V3, Arctic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, gelu_mlp, swiglu


def init_moe_params(key, cfg, dtype):
    d = cfg.d_model
    eff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),  # fp32 router
        "w_gate": dense_init(ks[1], (E, d, eff), dtype=dtype),
        "w_up": dense_init(ks[2], (E, d, eff), dtype=dtype),
        "w_down": dense_init(ks[3], (E, eff, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        se = cfg.n_shared_experts * eff
        p["shared"] = init_dense_ffn(ks[4], cfg, dtype, d_ff=se)
    if cfg.moe_dense_residual:
        p["dense_res"] = init_dense_ffn(ks[5], cfg, dtype, d_ff=cfg.d_ff)
    return p


def init_dense_ffn(key, cfg, dtype, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d, ff), dtype=dtype),
            "w_down": dense_init(ks[2], (ff, d), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, ff), dtype=dtype),
        "b_up": jnp.zeros((ff,), dtype),
        "w_down": dense_init(ks[1], (ff, d), dtype=dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def dense_ffn(p, cfg, x):
    if "w_gate" in p:
        if cfg.ffn_kind == "geglu":  # gemma2: gelu-gated
            g = jnp.einsum("...d,df->...f", x, p["w_gate"])
            u = jnp.einsum("...d,df->...f", x, p["w_up"])
            return jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u, p["w_down"])
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return gelu_mlp(x, p["w_up"], p["w_down"], p.get("b_up"), p.get("b_down"))


def router_topk(router_w, x, top_k: int):
    """Returns (weights (T,k) fp32, expert ids (T,k), aux load-balance loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e (fraction_routed_e * mean_prob_e)
    E = router_w.shape[-1]
    onehot = jax.nn.one_hot(ids[:, 0], E)
    aux = E * jnp.sum(jnp.mean(onehot, 0) * jnp.mean(probs, 0))
    return w, ids, aux


def _dispatch_tables(ids, weights, n_experts: int, capacity: int):
    """Sort-based dispatch: (T,k) assignments -> (E, C) token-index tables.

    Returns (token_idx (E,C) int32, gate (E,C) fp32); empty slots point at
    token 0 with gate 0.
    """
    T, k = ids.shape
    flat_e = ids.reshape(-1)                      # (T*k,)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]

    counts = jnp.bincount(flat_e, length=n_experts)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k) - offsets[e_sorted]      # rank within expert
    keep = pos_in_e < capacity

    slot = jnp.where(keep, e_sorted * capacity + pos_in_e, n_experts * capacity)
    token_idx = jnp.zeros((n_experts * capacity + 1,), jnp.int32).at[slot].set(
        t_sorted, mode="drop"
    )[:-1].reshape(n_experts, capacity)
    gate = jnp.zeros((n_experts * capacity + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w_sorted, 0.0), mode="drop"
    )[:-1].reshape(n_experts, capacity)
    return token_idx, gate


def _expert_compute(p, x_ec):
    """x_ec: (E, C, d) -> (E, C, d) through each expert's swiglu."""
    g = jnp.einsum("ecd,edf->ecf", x_ec, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_ec, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])


def moe_ffn_local(p, cfg, x, *, expert_slice=None, n_total_experts=None):
    """MoE over flat tokens x: (T, d). Routing is over ALL experts; compute
    covers `expert_slice` (lo, size) when running as one expert-parallel shard
    (router outputs for non-local experts are masked out; psum happens in the
    shard_map wrapper).
    """
    T, d = x.shape
    E = n_total_experts or cfg.n_experts
    w, ids, aux = router_topk(p["router"], x, cfg.top_k)

    if expert_slice is not None:
        lo, size = expert_slice
        local = (ids >= lo) & (ids < lo + size)
        ids_local = jnp.where(local, ids - lo, size)       # size = drop bucket
        w = jnp.where(local, w, 0.0)
        n_exp = size
        capacity = max(
            int(cfg.capacity_factor * cfg.top_k * T * size / E), cfg.top_k
        )
        ids_for_dispatch = jnp.where(local, ids_local, n_exp)  # overflow slot
        # use n_exp+1 buckets, last one dropped via capacity table bounds
        token_idx, gate = _dispatch_tables(
            jnp.minimum(ids_for_dispatch, n_exp), w, n_exp + 1, capacity
        )
        token_idx, gate = token_idx[:n_exp], gate[:n_exp]
    else:
        n_exp = E
        capacity = max(int(cfg.capacity_factor * cfg.top_k * T / E), cfg.top_k)
        token_idx, gate = _dispatch_tables(ids, w, n_exp, capacity)

    x_ec = x[token_idx]                                    # (E_local, C, d)
    y_ec = _expert_compute(p, x_ec) * gate[..., None].astype(x.dtype)
    out = jnp.zeros_like(x).at[token_idx.reshape(-1)].add(
        y_ec.reshape(-1, d), mode="drop"
    )

    if "shared" in p:
        out = out + dense_ffn(p["shared"], cfg, x)
    if "dense_res" in p:
        out = out + dense_ffn(p["dense_res"], cfg, x)
    return out, aux


def moe_ffn_2d(p, cfg, x, mesh, model_axis: str = "model"):
    """Serving layout: experts on 'model' x expert-d_ff on 'data'; tokens
    replicated across the whole mesh (decode batches are tiny, expert weights
    are not — this removes the per-step FSDP weight all-gathers entirely;
    DESIGN.md §4, §Perf)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, S, d = x.shape
    flat = x.reshape(-1, d)
    n_model = mesh.shape[model_axis]
    ff_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    e_local = cfg.n_experts // n_model

    def shard_fn(p_sh, x_sh):
        idx = jax.lax.axis_index(model_axis)
        p_experts = {k: v for k, v in p_sh.items() if k not in ("shared", "dense_res")}
        out, aux = moe_ffn_local(
            p_experts, cfg, x_sh,
            expert_slice=(idx * e_local, e_local),
            n_total_experts=cfg.n_experts,
        )
        out = jax.lax.psum(out, mesh.axis_names)     # experts + d_ff partials
        aux = jax.lax.pmean(aux, mesh.axis_names)
        if "shared" in p_sh:
            out = out + dense_ffn(p_sh["shared"], cfg, x_sh)
        if "dense_res" in p_sh:
            out = out + dense_ffn(p_sh["dense_res"], cfg, x_sh)
        return out, aux

    p_specs = {
        "router": P(),
        "w_gate": P(model_axis, None, ff_axes),
        "w_up": P(model_axis, None, ff_axes),
        "w_down": P(model_axis, ff_axes, None),
    }
    for extra in ("shared", "dense_res"):
        if extra in p:
            p_specs[extra] = jax.tree.map(lambda _: P(), p[extra])

    out, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(p_specs, P()),        # tokens replicated everywhere
        out_specs=(P(), P()),
        check_rep=False,
    )(p, flat)
    return out.reshape(B, S, d), aux


def moe_ffn(p, cfg, x, mesh=None, model_axis: str = "model"):
    """(B, S, d) MoE FFN; expert-parallel over `model_axis` when mesh given."""
    B, S, d = x.shape
    flat = x.reshape(-1, d)

    if mesh is None or mesh.shape.get(model_axis, 1) == 1:
        out, aux = moe_ffn_local(p, cfg, flat)
        return out.reshape(B, S, d), aux

    if getattr(cfg, "moe_2d", False):
        return moe_ffn_2d(p, cfg, x, mesh, model_axis)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[model_axis]
    e_local = cfg.n_experts // n_shards
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    dp_size = 1
    for a in data_axes:
        dp_size *= mesh.shape[a]
    if flat.shape[0] % dp_size != 0:
        data_axes = ()  # e.g. decode with batch 1: replicate tokens

    def shard_fn(p_sh, x_sh):
        idx = jax.lax.axis_index(model_axis)
        # shared/dense-residual paths are replicated; run on shard 0 only
        p_experts = {k: v for k, v in p_sh.items() if k not in ("shared", "dense_res")}
        out, aux = moe_ffn_local(
            p_experts, cfg, x_sh,
            expert_slice=(idx * e_local, e_local),
            n_total_experts=cfg.n_experts,
        )
        out = jax.lax.psum(out, model_axis)
        # per-shard load-balance estimator averaged over the whole mesh (the
        # E*sum(f_e p_e) statistic is nonlinear in the token set, so this is
        # an estimator of — not identical to — the global-batch aux loss)
        aux = jax.lax.pmean(aux, mesh.axis_names)
        if "shared" in p_sh:
            out = out + dense_ffn(p_sh["shared"], cfg, x_sh)
        if "dense_res" in p_sh:
            out = out + dense_ffn(p_sh["dense_res"], cfg, x_sh)
        return out, aux

    expert_spec = P(model_axis)
    p_specs = {
        "router": P(),
        "w_gate": expert_spec, "w_up": expert_spec, "w_down": expert_spec,
    }
    for extra in ("shared", "dense_res"):
        if extra in p:
            p_specs[extra] = jax.tree.map(lambda _: P(), p[extra])

    out, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(p_specs, P(data_axes if data_axes else None)),
        out_specs=(P(data_axes if data_axes else None), P()),
        check_rep=False,
    )(p, flat)
    return out.reshape(B, S, d), aux
