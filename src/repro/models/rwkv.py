"""RWKV6 "Finch" blocks (arXiv:2404.05892): data-dependent decay WKV.

Time-mix: per-head linear-attention state S in R^{hd x hd} updated with a
*data-dependent* per-channel decay w_t (the RWKV6 contribution):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Prefill uses a sequential lax.scan with O(B*H*hd^2) carry (the Pallas
`rwkv6_scan` kernel is the TPU chunked-parallel path; this is its oracle).
Decode is one step with carried (token-shift, S) state. Channel-mix is the
RWKV squared-relu FFN. Simplification vs the released model: token-shift uses
learned static lerp weights (the low-rank data-dependent *decay* is kept,
per-token-shift LoRA omitted) — noted in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_rwkv_params(key, cfg, dtype):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    lora = 64
    ks = jax.random.split(key, 12)
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),            # shift-mix for r,k,v,g,w
        "wr": dense_init(ks[0], (d, d), dtype=dtype),
        "wk": dense_init(ks[1], (d, d), dtype=dtype),
        "wv": dense_init(ks[2], (d, d), dtype=dtype),
        "wg": dense_init(ks[3], (d, d), dtype=dtype),
        "w_lora_a": dense_init(ks[4], (d, lora), dtype=dtype),
        "w_lora_b": dense_init(ks[5], (lora, d), scale=0.01, dtype=dtype),
        "w_bias": jnp.full((d,), -6.0, dtype),          # slow default decay
        "u": dense_init(ks[6], (H, hd), dtype=dtype),   # bonus
        "ln_g": jnp.ones((d,), dtype),                  # per-head groupnorm
        "ln_b": jnp.zeros((d,), dtype),
        "wo": dense_init(ks[7], (d, d), dtype=dtype),
        # channel mix
        "mu_c": 0.5 * jnp.ones((2, d), dtype),
        "ck": dense_init(ks[8], (d, cfg.d_ff), dtype=dtype),
        "cv": dense_init(ks[9], (cfg.d_ff, d), dtype=dtype),
        "cr": dense_init(ks[10], (d, d), dtype=dtype),
    }


def _shift(x, x_prev):
    """Token shift: previous token's features ((B,S,d), carry (B,d))."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _decay(p, xw):
    """Data-dependent per-channel decay in (0,1): exp(-exp(.))."""
    loraw = jnp.einsum("...d,dl->...l", xw, p["w_lora_a"])
    loraw = jnp.einsum("...l,ld->...d", jnp.tanh(loraw), p["w_lora_b"])
    return jnp.exp(-jnp.exp((p["w_bias"] + loraw).astype(jnp.float32)))


def _group_norm(y, g, b, H, eps=1e-5):
    B, S, d = y.shape
    yh = y.reshape(B, S, H, d // H).astype(jnp.float32)
    mean = jnp.mean(yh, -1, keepdims=True)
    var = jnp.var(yh, -1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, S, d) * g + b).astype(y.dtype)


def time_mix(p, cfg, x, state):
    """x: (B,S,d); state: {"shift": (B,d), "wkv": (B,H,hd,hd)} -> (y, state)."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    xs = _shift(x, state["shift"])
    mu = p["mu"]
    r = jnp.einsum("bsd,de->bse", _mix(x, xs, mu[0]), p["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, mu[1]), p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, mu[2]), p["wv"]).reshape(B, S, H, hd)
    g = jnp.einsum("bsd,de->bse", _mix(x, xs, mu[3]), p["wg"])
    w = _decay(p, _mix(x, xs, mu[4])).reshape(B, S, H, hd)      # fp32 (B,S,H,hd)

    u = p["u"].astype(jnp.float32)

    def step(S_state, inp):
        r_t, k_t, v_t, w_t = inp                                 # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]               # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_state + u[..., :, None] * kv)
        S_new = w_t[..., :, None] * S_state + kv
        return S_new, y

    rs, ks_, vs, ws = (t.swapaxes(0, 1).astype(jnp.float32) for t in (r, k, v, w))
    unroll = min(getattr(cfg, "scan_unroll", 1), S)
    S_new, ys = jax.lax.scan(
        step, state["wkv"].astype(jnp.float32), (rs, ks_, vs, ws), unroll=unroll
    )
    y = ys.swapaxes(0, 1).reshape(B, S, d)
    y = _group_norm(y, p["ln_g"].astype(jnp.float32), p["ln_b"].astype(jnp.float32), H)
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["wo"])
    return out, {"shift": x[:, -1], "wkv": S_new.astype(state["wkv"].dtype)}


def channel_mix(p, cfg, x, state):
    """RWKV FFN. state: {"shift_c": (B,d)}."""
    xs = _shift(x, state["shift_c"])
    mu = p["mu_c"]
    k = jnp.einsum("bsd,df->bsf", _mix(x, xs, mu[0]), p["ck"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["cv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _mix(x, xs, mu[1]), p["cr"]))
    return r * kv, {"shift_c": x[:, -1]}


def init_rwkv_state(cfg, batch, dtype):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    return {
        "shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift_c": jnp.zeros((batch, d), dtype),
    }
