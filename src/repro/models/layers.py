"""Shared layer primitives (raw JAX): norms, RoPE, inits, FFNs."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, gamma, eps=1e-6):
    """RMSNorm with fp32 statistics but bf16 scaling.

    Upcasting the whole tensor (x.astype(f32) * ...) makes XLA hoist the
    convert into the remat-saved residual, doubling the activation stack
    (measured: 18.4 GiB f32 vs 9.2 GiB bf16 per stage for gemma2-9b, §Perf).
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return x * scale * (1.0 + gamma.astype(x.dtype))


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_up, w_down, b_up=None, b_down=None):
    h = jnp.einsum("...d,df->...f", x, w_up)
    if b_up is not None:
        h = h + b_up
    h = jax.nn.gelu(h)
    out = jnp.einsum("...f,fd->...d", h, w_down)
    if b_down is not None:
        out = out + b_down
    return out


def cross_entropy(logits, labels, mask=None):
    """Token CE in float32; labels < 0 are ignored.

    The gold logit is picked via a one-hot reduction rather than
    take_along_axis: with vocab-sharded logits, GSPMD keeps the
    select+reduce fused and sharded, while a gather along the sharded vocab
    axis re-materialises the full (B, S, V) tensor per device.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), V, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    valid = (labels >= 0) if mask is None else (mask & (labels >= 0))
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
