"""Sharding rules: parameter PartitionSpecs + activation constraints.

Mesh axes: ('data', 'model') single pod; ('pod', 'data', 'model') multi-pod.
Batch shards on ('pod','data') (together: DP); weights on 'model' (TP/EP):

  attention q/k/v on heads, o on heads (GSPMD pads non-divisible head counts,
  e.g. arctic's 56) · FFN on d_ff · experts on the expert axis (exact:
  128/256/16 % 16 == 0, matching the shard_map specs in repro.models.moe) ·
  embeddings on d_model, LM head on vocab · norms/scalars replicated.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh) -> P:
    return P(data_axes(mesh))


_RULES = {
    # --- embeddings / head ---
    # embed is sharded on VOCAB: with tied embeddings a d-sharded embed makes
    # the head contract over the sharded dim, and GSPMD materialises fully
    # replicated f32 (B,S,V) logits (62.5 GiB/device for gemma2) before any
    # output constraint can shard them (§Perf gemma iteration 3)
    "embed": lambda nd: P("model", None),
    "head": lambda nd: P(None, "model"),
    "frontend_proj": lambda nd: P(None, "model"),
    # --- attention (3D) / rwkv projections (2D) share names ---
    "wq": lambda nd: P(None, "model", None) if nd == 3 else P(None, "model"),
    "wk": lambda nd: P(None, "model", None) if nd == 3 else P(None, "model"),
    "wv": lambda nd: P(None, "model", None) if nd == 3 else P(None, "model"),
    "wo": lambda nd: P("model", None, None) if nd == 3 else P("model", None),
    "bq": lambda nd: P("model", None),
    "bk": lambda nd: P("model", None),
    "bv": lambda nd: P("model", None),
    # --- MLA ---
    "w_dq": lambda nd: P(None, "model"),
    "w_uq": lambda nd: P(None, "model", None),
    "w_dkv": lambda nd: P(),
    "w_uk": lambda nd: P(None, "model", None),
    "w_uv": lambda nd: P(None, "model", None),
    # --- dense FFN ---
    "w_gate": lambda nd: P(None, "model") if nd == 2 else P("model", None, None),
    "w_up": lambda nd: P(None, "model") if nd == 2 else P("model", None, None),
    "w_down": lambda nd: P("model", None) if nd == 2 else P("model", None, None),
    "b_up": lambda nd: P("model"),
    "b_down": lambda nd: P(),
    # --- MoE (3D expert tensors hit the nd==3 branches above) ---
    "router": lambda nd: P(),
    # --- mamba ---
    "in_proj": lambda nd: P(None, "model"),
    "conv_w": lambda nd: P(None, "model"),
    "conv_b": lambda nd: P("model"),
    "x_proj": lambda nd: P("model", None),
    "dt_proj": lambda nd: P(None, "model"),
    "dt_bias": lambda nd: P("model"),
    "A_log": lambda nd: P("model", None),
    "D": lambda nd: P("model"),
    "out_proj": lambda nd: P("model", None),
    # --- rwkv ---
    "wr": lambda nd: P(None, "model"),
    "wk_r": lambda nd: P(None, "model"),
    "wg": lambda nd: P(None, "model"),
    "w_lora_a": lambda nd: P(),
    "w_lora_b": lambda nd: P(None, "model"),
    "w_bias": lambda nd: P("model"),
    "u": lambda nd: P("model", None),
    "ln_g": lambda nd: P("model"),
    "ln_b": lambda nd: P("model"),
    "ck": lambda nd: P(None, "model"),
    "cv": lambda nd: P("model", None),
    "cr": lambda nd: P(None, "model"),
    "mu": lambda nd: P(),
    "mu_c": lambda nd: P(),
}

# rwkv wk/wv collide with attention names on purpose (same (d,d)->(None,model))


def _spec_for_leaf(path, leaf) -> P:
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    name = names[-1] if names else ""
    nd = getattr(leaf, "ndim", 0)
    in_stage = "stages" in names
    rule = _RULES.get(name)
    if rule is None:
        spec = P()                       # norms, scalars -> replicated
    else:
        spec = rule(nd - (1 if in_stage else 0))
    if in_stage:                          # stacked period dim
        spec = P(*((None,) + tuple(spec)))
    return spec


def param_specs(params):
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(_spec_for_leaf, params)


def param_shardings(mesh, params):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params))


def sanitize_specs(mesh, specs, tree):
    """Drop (to replicated) any spec axis whose dim doesn't divide the mesh
    axes — jit in_shardings requires exact divisibility (e.g. hubert's
    504-class head can't shard 16 ways)."""

    def leaf(spec, arr):
        dims = list(spec)
        changed = False
        for i, ax in enumerate(dims):
            if ax is None or i >= arr.ndim:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if arr.shape[i] % size != 0:
                dims[i] = None
                changed = True
        return P(*dims) if changed else spec

    return jax.tree.map(leaf, specs, tree)


def opt_state_specs(opt_state, params):
    """AdamW mu/nu mirror the param specs; step is replicated."""
    from repro.optim.optimizers import OptState

    pspecs = param_specs(params)
    return OptState(
        step=P(),
        mu=None if opt_state.mu is None else pspecs,
        nu=None if opt_state.nu is None else pspecs,
    )


def batch_specs(mesh, batch):
    """Shard the leading (batch) dim of every batch leaf on ('pod','data');
    leaves whose batch dim is not divisible by the DP size (e.g. long_500k's
    batch of 1) are replicated instead."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def leaf(x):
        nd = getattr(x, "ndim", 0)
        if nd == 0 or x.shape[0] % dp_size != 0:
            return P()
        return P(*((dp,) + (None,) * (nd - 1)))

    return jax.tree.map(leaf, batch)


def constrain(x, mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# KV-cache / recurrent-state sharding (leaves carry a leading period-stack dim)
# ---------------------------------------------------------------------------

_CACHE_RULES = {
    "k": ("B", None, "model", None),
    "v": ("B", None, "model", None),
    "pos_tag": (None,),
    "c_kv": ("B", None, "model"),
    "k_rope": ("B", None, None),
    "conv": ("B", None, "model"),
    "h": ("B", "model", None),
    "shift": ("B", "model"),
    "shift_c": ("B", "model"),
    "wkv": ("B", "model", None, None),
}


def cache_specs(mesh, cache):
    """PartitionSpecs for a decode cache pytree (from models.model.init_cache)."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def leaf(path, x):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        rule = _CACHE_RULES.get(name)
        if rule is None:
            return P()
        spec = []
        for axis, dim in zip(rule, x.shape[1:]):
            if axis == "B":
                spec.append(dp if dim % dp_size == 0 and dp else None)
            else:
                spec.append(axis)
        return P(*([None] + spec))  # leading period-stack dim replicated

    return jax.tree_util.tree_map_with_path(leaf, cache)
