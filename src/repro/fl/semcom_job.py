"""The closed FedSem loop: FL-train the paper's SemCom autoencoder with the
allocator's per-round rho reconfiguring the codec, and feed measured accuracy
back into the allocator's A(rho) model.

This is the piece the paper describes but the repo lacked: `fl.federated`
trained toy models against pre-planned allocations, `semcom.autoencoder` was
never trained by the FL driver, and the A(rho) curve steering eq. 13 was the
paper's fixed YOLO fit. A `SemComJob` wires all three together:

  * the round's solved rho enters the codec as a RUNTIME bottleneck
    (`autoencoder.latent_mask` keeps ceil(rho * base_latent) latent channels;
    the paper's extra pooling stage for rho <= 0.5 is a `jax.lax.cond`
    branch) — parameters stay at the rho = 1 shape, so FedAvg aggregates
    across rounds with different rho, and the top-|rho| upload sparsification
    in `run_fl` compresses the update stream with the same rho;
  * after each round the job measures `proxy_accuracy` through the codec at
    the round's rho plus fixed probe rhos, and once enough measurements
    accumulate it re-fits ``A(rho) = a rho^b`` (`core.accuracy.fit_power_law`,
    clipped to Assumption 1: increasing + concave) and pushes the fit into a
    live backend via `AllocationBackend.set_accuracy` — subsequent rounds are
    then allocated against the job's OWN accuracy curve instead of the
    paper's (the feedback edge). `PlannedBackend` declines the push (it
    solved every round up front); the refusal is recorded, not an error.

Feedback changes answers by design, so the ServiceBackend == PlannedBackend
equivalence gate runs with ``feedback=False`` (or at the backend level,
below `run_fl`) — see `repro.launch.fedsem_e2e`.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import AccuracyFn, fit_power_law
from repro.data.synthetic import image_batch
from repro.fl.alloc_backend import AllocationBackend
from repro.fl.federated import FLConfig, RoundStats, run_fl
from repro.semcom.autoencoder import (
    AEConfig,
    init_params,
    mse_loss_rho,
    proxy_accuracy_rho,
)


class SemComJobConfig(NamedTuple):
    fl: FLConfig = FLConfig(
        n_clients=4, n_subcarriers=12, rounds=6, local_steps=2
    )
    ae: AEConfig = AEConfig(hidden=8)
    batch_size: int = 8
    eval_batch: int = 16
    #: measure accuracy at these rhos every round besides the solved one, so
    #: the refit always sees rho diversity (solved rhos can cluster tightly)
    probe_rhos: tuple = (0.25, 0.75)
    #: rounds of measurements to accumulate before the first refit
    refit_after: int = 2
    #: push refits into the backend (`set_accuracy`); False keeps measuring
    #: but never changes the allocator's curve — the equivalence-gate mode
    feedback: bool = True
    name: str = "semcom"


class SemComJobResult(NamedTuple):
    name: str
    params: dict
    history: list[RoundStats]
    #: every (rho, proxy_accuracy) measurement, solved and probe rhos alike
    measurements: list[tuple[float, float]]
    #: the last A(rho) re-fit (None when too few rounds ran to fit)
    accuracy_fit: AccuracyFn | None
    #: True iff a fit was pushed into the backend and the backend took it
    refit_applied: bool
    #: round index of the FIRST applied refit (None if never applied)
    refit_round: int | None


class SemComJob:
    """One FL job training the SemCom autoencoder (see module docstring).

    ``run(key, backend=None)`` drives `run_fl` with the codec's rho-aware
    loss; the default backend is the offline planner, a `ServiceBackend`
    closes the loop through the live serving stack.
    """

    def __init__(self, cfg: SemComJobConfig = SemComJobConfig()):
        # params live at the rho = 1 shape; rho is applied at runtime
        self.ae = cfg.ae._replace(rho=1.0)
        self.cfg = cfg._replace(fl=cfg.fl._replace(rho_in_loss=True))
        ae = self.ae

        def loss_fn(p, batch, k, rho):
            # the paper's extra pooling stage (rho <= 0.5) changes
            # intermediate shapes, so it is a cond branch, not arithmetic
            return jax.lax.cond(
                rho <= 0.5,
                lambda: mse_loss_rho(p, ae, batch, rho, k, extra_pool=True),
                lambda: mse_loss_rho(p, ae, batch, rho, k, extra_pool=False),
            )

        def batch_fn(k, client_idx):
            del client_idx  # synthetic shards differ through the key only
            return image_batch(
                k, cfg.batch_size, size=ae.image_size, channels=ae.channels
            )

        @partial(jax.jit, static_argnames="extra_pool")
        def eval_acc(params, x, rho, key, extra_pool):
            return proxy_accuracy_rho(
                params, ae, x, rho, key=key, extra_pool=extra_pool
            )

        self._loss_fn = loss_fn
        self._batch_fn = batch_fn
        self._eval_acc = eval_acc

    def _measure(self, params, x_eval, key, rho: float) -> float:
        return float(
            self._eval_acc(
                params, x_eval, jnp.float32(rho), key, extra_pool=rho <= 0.5
            )
        )

    def run(
        self, key: jax.Array, backend: AllocationBackend | None = None
    ) -> SemComJobResult:
        cfg = self.cfg
        k_init, k_eval, k_fl = jax.random.split(key, 3)
        params0 = init_params(k_init, self.ae)
        x_eval = image_batch(
            k_eval, cfg.eval_batch, size=self.ae.image_size,
            channels=self.ae.channels,
        )

        measurements: list[tuple[float, float]] = []
        state = {"fit": None, "applied": False, "round": None}

        def hook(rnd: int, params, alloc, stats: RoundStats) -> None:
            k_ch = jax.random.fold_in(k_eval, rnd)  # fixed eval channel draw
            for rho in (float(alloc.rho), *cfg.probe_rhos):
                measurements.append(
                    (rho, self._measure(params, x_eval, k_ch, rho))
                )
            if rnd + 1 < cfg.refit_after:
                return
            rhos, accs = zip(*measurements)
            state["fit"] = fit_power_law(jnp.asarray(rhos), jnp.asarray(accs))
            if cfg.feedback and backend is not None:
                if backend.set_accuracy(state["fit"]) and not state["applied"]:
                    state["applied"] = True
                    state["round"] = rnd

        params, history = run_fl(
            k_fl,
            params0,
            self._loss_fn,
            self._batch_fn,
            cfg.fl,
            backend=backend,
            round_hook=hook,
        )
        return SemComJobResult(
            name=cfg.name,
            params=params,
            history=history,
            measurements=measurements,
            accuracy_fit=state["fit"],
            refit_applied=state["applied"],
            refit_round=state["round"],
        )
