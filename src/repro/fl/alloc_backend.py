"""Pluggable allocation backends: where `run_fl` gets each round's resources.

The FL driver used to hard-code the offline path — one batched `solve_batch`
over every round's pre-sampled scenario before training starts. That path is
now `PlannedBackend` (bit-identical, regression-tested); `ServiceBackend`
instead submits each round's `SystemParams` to the live serving stack
(`AllocService` on a virtual clock, or a `RealClockDriver` / its asyncio
facade) and blocks on the answer, which is how many concurrent FL jobs share
one allocation service and how a job's re-fit A(rho) can steer its own later
rounds (`repro.fl.semcom_job`).

Equivalence spine (tests/test_fl_backend.py, `fedsem_e2e --smoke`): for the
same round scenarios and the same `AllocatorConfig`, `ServiceBackend` over
the virtual-clock service returns the EXACT hardened assignment X that
`PlannedBackend` computes — padding into shape buckets and co-batching are
answer-transparent (docs/ARCHITECTURE.md guarantee table), so routing the FL
loop through the service changes scheduling, never answers.
"""
from __future__ import annotations

from typing import Sequence

from repro.core import (
    Allocation,
    AllocatorConfig,
    AllocatorResult,
    SystemParams,
    Weights,
    solve_batch,
    stack_params,
    tree_index,
)
from repro.serve.driver import RealClockDriver
from repro.serve.service import AllocService, ServeConfig
from repro.serve.warmstart import entry_from_alloc


class AllocationBackend:
    """Protocol for `run_fl`'s per-round allocation source.

    Lifecycle: `open(scenarios, weights)` once with every round's
    `SystemParams` (the FL driver samples them, so all backends price
    identical channels), `allocate(rnd)` per round (blocking until the
    round's `Allocation` is available), `close()` when the run ends.

    `close` releases only what the backend itself created — externally
    provided services/drivers stay up, so one driver can serve many jobs.
    `set_accuracy` offers a re-fit A(rho) model for later rounds and returns
    whether it took effect; `supports_accuracy_feedback` advertises the
    answer up front (the offline planner solved everything already and must
    decline, the live service re-solves each round and accepts).
    """

    supports_accuracy_feedback: bool = False

    def open(self, scenarios: Sequence[SystemParams], weights: Weights) -> None:
        raise NotImplementedError

    def allocate(self, rnd: int) -> Allocation:
        raise NotImplementedError

    def set_accuracy(self, acc) -> bool:
        return False

    def close(self) -> None:
        pass


class PlannedBackend(AllocationBackend):
    """Today's offline path: one batched, jitted solve for every round before
    training starts (`repro.core.solve_batch` — one trace/compile per run).

    `fl.federated.plan_allocations` is a thin wrapper over this class; the
    batched result is exposed as ``sys_batch`` / ``result`` for callers that
    want the whole plan (fig8 benchmark, regression tests).
    """

    supports_accuracy_feedback = False

    def __init__(
        self,
        allocator: AllocatorConfig = AllocatorConfig(inner="pgd"),
        accuracy=None,
    ):
        self.allocator = allocator
        self.accuracy = accuracy
        self.sys_batch: SystemParams | None = None
        self.result: AllocatorResult | None = None

    def open(self, scenarios: Sequence[SystemParams], weights: Weights) -> None:
        self.sys_batch = stack_params(list(scenarios))
        self.result = solve_batch(
            self.sys_batch, weights, self.allocator, self.accuracy
        )

    def allocate(self, rnd: int) -> Allocation:
        return tree_index(self.result.alloc, rnd)


class ServiceBackend(AllocationBackend):
    """Round allocations served by the live allocation stack.

    ``target`` is either:

    * an `AllocService` — sans-IO virtual-clock mode: each round is admitted
      at virtual time ``rnd`` and drained immediately (a batch of one, which
      co-batching transparency makes answer-identical to any fill level).
      Single-tenant only — `drain` flushes every queue, so don't point two
      jobs at one bare service; share a driver instead.
    * a `RealClockDriver` — ``submit`` returns a future, `allocate` blocks
      on it; many jobs (threads) share one driver and their rounds co-batch
      inside the service's micro-batcher.
    * a `repro.serve.aio.AsyncAllocDriver` — the asyncio facade is unwrapped
      to its underlying driver (this backend is sync; async callers can also
      await the facade directly and skip `run_fl`).

    The target is borrowed, never owned: `close` leaves it running.

    ``warm_rounds=True`` turns on round-to-round solution reuse: each round's
    request carries the PREVIOUS round's hardened (f, P, X) as an explicit
    warm-start entry (`repro.serve.warmstart.CacheEntry`, injected through
    ``submit(..., warm_start=...)``). FL rounds are exactly the recurring-user
    workload the warm-start cache targets — same devices, slowly drifting
    channels — and the multi-start dominance argument applies unchanged: the
    round's objective can only improve or tie versus a cold solve, and the
    allocation the training step sees is still hardened and feasible. Works
    with or without the service's own cache enabled (an explicit entry
    overrides the cache lookup).

    ``tenant`` scopes this backend's accuracy feedback to ITS OWN rounds:
    every submit carries the tenant id and `set_accuracy` updates only that
    tenant's registry entry (`AllocService.set_accuracy(acc, tenant=...)`),
    so concurrent jobs sharing one driver never see each other's refits —
    bit-for-bit (the multi-tenant non-interference row,
    tests/test_fl_backend.py and `fedsem_e2e`). None keeps the legacy
    all-tenants default behaviour.
    """

    supports_accuracy_feedback = True

    def __init__(
        self,
        target,
        *,
        timeout_s: float = 600.0,
        warm_rounds: bool = False,
        tenant=None,
    ):
        target = getattr(target, "driver", target)  # unwrap the asyncio facade
        if isinstance(target, RealClockDriver):
            self._driver: RealClockDriver | None = target
            self._service = target.service
        elif isinstance(target, AllocService):
            self._driver = None
            self._service = target
        else:
            raise TypeError(
                "ServiceBackend target must be an AllocService, a "
                f"RealClockDriver or an AsyncAllocDriver, got {type(target)!r}"
            )
        self._timeout_s = timeout_s
        self._warm_rounds = warm_rounds
        self.tenant = tenant
        self._prev_alloc: Allocation | None = None
        self._scenarios: list[SystemParams] = []
        self._weights: Weights | None = None

    def open(self, scenarios: Sequence[SystemParams], weights: Weights) -> None:
        self._scenarios = list(scenarios)
        self._weights = weights
        self._prev_alloc = None

    def _warm_entry(self, params: SystemParams):
        """Previous round's solution as a warm-start entry — only when shapes
        still match (a population change mid-run resets the chain)."""
        if not self._warm_rounds or self._prev_alloc is None:
            return None
        prev = self._prev_alloc
        if prev.X.shape != (params.N, params.K):
            return None
        return entry_from_alloc(prev)

    def allocate(self, rnd: int) -> Allocation:
        params = self._scenarios[rnd]
        warm = self._warm_entry(params)
        if self._driver is not None:
            fut = self._driver.submit(
                params, self._weights, warm_start=warm, tenant=self.tenant
            )
            alloc = fut.result(timeout=self._timeout_s).alloc
        else:
            req_id = self._service.submit(
                params, self._weights, now=float(rnd), warm_start=warm,
                tenant=self.tenant,
            )
            done, _ = self._service.drain(now=float(rnd))
            alloc = next(c.alloc for c in done if c.req_id == req_id)
        if self._warm_rounds:
            self._prev_alloc = alloc
        return alloc

    def set_accuracy(self, acc) -> bool:
        self._service.set_accuracy(acc, tenant=self.tenant)
        return True


def serve_config_for(allocator: AllocatorConfig, **overrides) -> ServeConfig:
    """A `ServeConfig` whose solver matches an FL run's `AllocatorConfig` —
    the precondition for the ServiceBackend == PlannedBackend hardened-X
    guarantee (the executable cache keys on the config, so a mismatched
    service would solve the same scenario with a different algorithm)."""
    return ServeConfig(allocator=allocator, **overrides)
