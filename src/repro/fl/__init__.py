"""repro.fl: the FL substrate, its pluggable allocation backends, and the
closed-loop SemCom training job."""
from .alloc_backend import (
    AllocationBackend, PlannedBackend, ServiceBackend, serve_config_for,
)
from .federated import (
    FLConfig, RoundStats, plan_allocations, round_channel_key, run_fl,
    sample_round_scenarios, topk_sparsify, tree_bits,
)
from .semcom_job import SemComJob, SemComJobConfig, SemComJobResult

__all__ = [
    "AllocationBackend", "PlannedBackend", "ServiceBackend", "serve_config_for",
    "FLConfig", "RoundStats", "plan_allocations", "round_channel_key",
    "run_fl", "sample_round_scenarios", "topk_sparsify", "tree_bits",
    "SemComJob", "SemComJobConfig", "SemComJobResult",
]
