"""repro.fl"""
