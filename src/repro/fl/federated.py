"""Federated-learning substrate (paper Stage 1) wired to the resource allocator.

All rounds' wireless scenarios are pre-sampled (block fading is i.i.d.
across rounds, paper §III) with per-client upload size D_n = rho-compressed
update bits and compute c_n d_n taken from the *actual* model being trained.
WHERE each round's allocation comes from is pluggable (`repro.fl.alloc_backend`):

  * `PlannedBackend` (default) — Alg. A2 allocates subcarriers / powers /
    CPU frequencies / rho for *every* round in one batched, jitted call
    (`repro.core.solve_batch`) before training starts;
  * `ServiceBackend` — each round's `SystemParams` is submitted to the live
    serving stack (`AllocService` / `RealClockDriver`) and the round blocks
    on its answer, so concurrent FL jobs share one allocation service.

Then, per FL round:
  1. every client runs `local_steps` of SGD on its shard (vmapped across
     clients), uploads a top-|rho| sparsified update (the LM-world analogue of
     the paper's semantic compression — DESIGN.md §5), and the server
     aggregates with FedAvg weights d_n;
  2. the round's energy/delay are computed from the round's allocation via
     the system model and accumulated into the history.

The driver is model-agnostic: pass any (init_params, loss_fn, batch_stream).
With ``cfg.rho_in_loss`` the loss also receives the round's solved rho as a
traced scalar — how the SemCom job reconfigures its bottleneck per round
without retracing (`repro.fl.semcom_job`).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import (
    AllocatorConfig,
    AllocatorResult,
    SystemParams,
    Weights,
    tree_bits,
)
from repro.core.system import report
from repro.fl.alloc_backend import AllocationBackend, PlannedBackend
from repro.optim.optimizers import sgd
from repro.scenarios import get_family


class FLConfig(NamedTuple):
    n_clients: int = 10
    n_subcarriers: int = 50
    rounds: int = 20
    local_steps: int = 5
    lr: float = 0.05
    kappa: tuple = (1.0, 1.0, 1.0)
    allocator_inner: str = "pgd"   # fast + strong inner for the driver
    compress: bool = True          # top-|rho| update sparsification
    scenario: str = "iid_rayleigh"  # registered scenario family for channels
    seed: int = 0
    #: call the loss as ``loss_fn(params, batch, key, rho)`` with the round's
    #: solved rho as a traced scalar (rho-aware models, e.g. the SemCom codec)
    rho_in_loss: bool = False


class RoundStats(NamedTuple):
    loss: float
    rho: float
    energy: float
    t_fl: float
    objective: float
    upload_bits: float


def round_channel_key(key: jax.Array, rnd: int) -> jax.Array:
    """Channel key for round ``rnd`` — shared by the batched planner and any
    sequential reference so both sample identical scenarios."""
    return jax.random.split(jax.random.fold_in(key, rnd), 3)[0]


def sample_round_scenarios(
    key: jax.Array, cfg: FLConfig, d_bits: float
) -> list[SystemParams]:
    """Pre-sample every round's wireless scenario from the `cfg.scenario`
    registry family (the default, ``iid_rayleigh``, draws bit-identically to
    the pre-registry sampler). Sampling lives in the FL driver — not in the
    backends — so every backend prices identical channels for a given key."""
    family = get_family(cfg.scenario)
    return [
        family.sample(
            round_channel_key(key, rnd),
            N=cfg.n_clients,
            K=cfg.n_subcarriers,
            D_bits=d_bits,
        )
        for rnd in range(cfg.rounds)
    ]


def plan_allocations(
    key: jax.Array, cfg: FLConfig, d_bits: float, weights: Weights
) -> tuple[SystemParams, AllocatorResult]:
    """Pre-sample every round's scenario and solve all allocations at once.

    Returns the batch-stacked ``SystemParams`` (leading axis = round) and the
    batched `AllocatorResult` from a single `solve_batch` call — one trace /
    compile for the whole FL run instead of one per round. This is
    `PlannedBackend`'s plan, exposed whole for callers that want it
    (fig8 benchmark, regression tests).
    """
    backend = PlannedBackend(AllocatorConfig(inner=cfg.allocator_inner))
    backend.open(sample_round_scenarios(key, cfg, d_bits), weights)
    return backend.sys_batch, backend.result


def topk_sparsify(update, frac):
    """Keep the largest-|.| `frac` of entries per leaf (rho-compression).

    jit-friendly via a per-leaf magnitude-quantile threshold.
    """

    def leaf_q(u):
        qt = jnp.quantile(jnp.abs(u.reshape(-1)), jnp.clip(1.0 - frac, 0.0, 1.0))
        return jnp.where(jnp.abs(u) >= qt, u, 0.0)

    return jax.tree.map(leaf_q, update)


def run_fl(
    key: jax.Array,
    init_params,
    loss_fn: Callable,            # loss_fn(params, batch, key[, rho]) -> scalar
    client_batch_fn: Callable,    # client_batch_fn(key, client_idx) -> batch
    cfg: FLConfig = FLConfig(),
    flops_per_sample: float = 1e6,
    backend: AllocationBackend | None = None,
    round_hook: Callable | None = None,
):
    """Run FL with per-round wireless resource allocation. Returns history.

    ``backend`` chooses the allocation source (default: a fresh
    `PlannedBackend` matching the pre-refactor behaviour exactly).
    ``round_hook(rnd, params, alloc, stats)`` runs after each round's
    aggregation — the hook a `SemComJob` uses to measure proxy accuracy at
    the round's rho and push an A(rho) refit back into a live backend.
    """
    params = init_params
    opt_init, opt_update = sgd(cfg.lr)
    w = Weights(*map(jnp.float32, cfg.kappa))
    d_bits = tree_bits(params)

    @jax.jit
    def local_train(params, batches, key, rho):
        """One client: `local_steps` SGD steps. batches: (steps, ...)."""
        state = opt_init(params)

        def step(carry, xs):
            p, s = carry
            batch, k = xs
            if cfg.rho_in_loss:
                loss, g = jax.value_and_grad(loss_fn)(p, batch, k, rho)
            else:
                loss, g = jax.value_and_grad(loss_fn)(p, batch, k)
            p, s = opt_update(g, s, p)
            return (p, s), loss

        keys = jax.random.split(key, cfg.local_steps)
        (p, _), losses = jax.lax.scan(step, (params, state), (batches, keys))
        delta = jax.tree.map(lambda a, b: a - b, p, params)
        return delta, jnp.mean(losses)

    multi_train = jax.jit(jax.vmap(local_train, in_axes=(None, 0, 0, None)))

    # --- resource allocation (paper core): sample every round's scenario,
    # then let the backend answer them — in one offline batched solve
    # (PlannedBackend) or round-by-round through the live service
    scenarios = sample_round_scenarios(key, cfg, d_bits)
    if backend is None:
        backend = PlannedBackend(AllocatorConfig(inner=cfg.allocator_inner))
    backend.open(scenarios, w)

    history: list[RoundStats] = []
    try:
        for rnd in range(cfg.rounds):
            k_round = jax.random.fold_in(key, rnd)
            _, k_data, k_train = jax.random.split(k_round, 3)

            sys_params = scenarios[rnd]
            alloc = backend.allocate(rnd)
            rho = float(alloc.rho)
            stats = report(sys_params, w, alloc)

            # --- local training (vmapped over clients) ---
            batches = jax.vmap(
                lambda i: jax.vmap(
                    lambda s: client_batch_fn(
                        jax.random.fold_in(k_data, i * 1000 + s), i
                    )
                )(jnp.arange(cfg.local_steps))
            )(jnp.arange(cfg.n_clients))
            deltas, losses = multi_train(
                params,
                batches,
                jax.random.split(k_train, cfg.n_clients),
                jnp.float32(rho),
            )

            # --- rho-compressed upload + FedAvg ---
            if cfg.compress:
                deltas = jax.vmap(lambda d: topk_sparsify(d, rho))(deltas)
            agg = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
            params = jax.tree.map(lambda p, d: p + d, params, agg)

            history.append(
                RoundStats(
                    loss=float(jnp.mean(losses)),
                    rho=rho,
                    energy=float(stats["energy_total"]),
                    t_fl=float(stats["t_fl"]),
                    objective=float(stats["objective"]),
                    upload_bits=rho * d_bits * cfg.n_clients,
                )
            )
            if round_hook is not None:
                round_hook(rnd, params, alloc, history[-1])
    finally:
        backend.close()
    return params, history
