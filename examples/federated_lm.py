"""Federated fine-tuning of an LM backbone with allocator-driven compression.

Any `--arch` from the assigned pool works (reduced smoke variant by default);
each round, Alg. A2 chooses the compression rate rho, which sparsifies the
clients' uploaded updates (top-|rho| magnitude), and the wireless energy and
delay of the round are simulated from the allocation.

  PYTHONPATH=src python examples/federated_lm.py --arch qwen2_5_3b --rounds 8
"""
import argparse

import jax

from repro.configs.registry import get_config
from repro.data.synthetic import make_bigram_table, token_batch
from repro.fl.federated import FLConfig, run_fl
from repro.models import model as M
from repro.models.config import smoke_variant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    table = make_bigram_table(jax.random.PRNGKey(7), cfg.vocab)

    def loss_fn(p, batch, k):
        return M.loss_fn(p, cfg, batch)

    def client_batch(k, i):
        toks = token_batch(k, table, 4, args.seq)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    fl_cfg = FLConfig(
        rounds=args.rounds, n_clients=args.clients,
        n_subcarriers=4 * args.clients, local_steps=2, lr=0.02, compress=True,
    )
    params, hist = run_fl(key, params, loss_fn, client_batch, fl_cfg)

    print(f"\n{'round':>5s} {'loss':>8s} {'rho':>5s} {'energy J':>9s} {'T_FL s':>7s}")
    for i, h in enumerate(hist):
        print(f"{i:5d} {h.loss:8.4f} {h.rho:5.2f} {h.energy:9.3f} {h.t_fl:7.3f}")
    assert hist[-1].loss < hist[0].loss, "FL did not reduce loss"
    print("\nFL reduced loss:", round(hist[0].loss - hist[-1].loss, 4),
          "| total upload:",
          f"{sum(h.upload_bits for h in hist)/8e6:.1f} MB (rho-compressed)")


if __name__ == "__main__":
    main()
