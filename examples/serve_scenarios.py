"""Serving demo: stream mixed-size scenarios through the micro-batched
allocation service and print each hardened answer plus the service metrics.

  PYTHONPATH=src python examples/serve_scenarios.py
"""
import jax

from repro.core import AllocatorConfig, Weights, bucket_for, sample_request_stream
from repro.core.pgd import PGDConfig
from repro.core.system import feasible, report
from repro.serve import AllocService, BatchPolicy, ServeConfig, poisson_arrivals, run_load


def main():
    key = jax.random.PRNGKey(0)
    # different (N, K) per request, same per-subcarrier bandwidth -> they pad
    # into shared ShapeBuckets and ride the same compiled batched solves
    requests = sample_request_stream(key, 8, sizes=((3, 8), (4, 8), (4, 12)))
    arrivals = poisson_arrivals(jax.random.fold_in(key, 1), len(requests), rate_hz=100.0)

    service = AllocService(
        ServeConfig(
            policy=BatchPolicy(max_batch=4, max_wait_s=0.02),
            allocator=AllocatorConfig(inner="pgd", outer_iters=3, pgd=PGDConfig(steps=200)),
        )
    )
    service.warmup(requests)                 # compile per bucket, ahead of traffic
    result = run_load(service, requests, arrivals)

    print(f"{'req':>3s} {'(N,K)':>8s} {'bucket':>8s} {'latency':>9s} "
          f"{'objective':>10s} {'rho':>5s} feasible")
    w = Weights.ones()
    for c in sorted(result.completions, key=lambda c: c.req_id):
        p = requests[c.req_id]
        r = report(p, w, c.alloc)
        print(f"{c.req_id:3d} {f'({p.N},{p.K})':>8s} "
              f"{f'({c.bucket[0]},{c.bucket[1]})':>8s} {c.latency_s*1e3:7.1f}ms "
              f"{float(r['objective']):10.3f} {float(r['rho']):5.2f} "
              f"{bool(feasible(p, c.alloc))}")

    s = result.summary
    print(f"\n{len(result.completions)} requests in {result.makespan_s*1e3:.0f}ms virtual "
          f"-> {result.throughput_rps:.1f} req/s | p50 {s['latency_p50_s']*1e3:.1f}ms "
          f"p95 {s['latency_p95_s']*1e3:.1f}ms | occupancy {s['batch_occupancy_mean']:.2f} "
          f"| {s['cache_misses']} compiles, {s['cache_hits']} cache hits")
    print("buckets used:", sorted({bucket_for(p.N, p.K) for p in requests}))


if __name__ == "__main__":
    main()
