"""End-to-end FedSem driver (the paper's own pipeline):

  Stage 1 — federated training of the SemCom CNN autoencoder across N
  simulated devices, with per-round wireless resource allocation (Alg. A2)
  pricing every round's energy/delay;
  Stage 2 — evaluate the trained codec at several compression rates rho,
  re-fit the concave accuracy curve A(rho) = a rho^b from our own
  measurements (paper Fig. 2 / Fig. 8b analogue), and write it where the
  benchmarks pick it up.

  PYTHONPATH=src python examples/fedsem_autoencoder.py --rounds 40
"""
import argparse
import csv
import pathlib

import jax
import jax.numpy as jnp

from repro.core.accuracy import fit_power_law
from repro.data.synthetic import image_batch
from repro.fl.federated import FLConfig, run_fl
from repro.semcom.autoencoder import (
    AEConfig, init_params, mse_loss, proxy_accuracy, psnr,
)

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def train_fedsem(rounds: int, rho: float, key):
    cfg = AEConfig(rho=rho)
    params = init_params(jax.random.fold_in(key, int(rho * 100)), cfg)

    def loss_fn(p, batch, k):
        return mse_loss(p, cfg, batch, k)

    def client_batch(k, i):
        return image_batch(k, 8)

    fl_cfg = FLConfig(rounds=rounds, n_clients=6, n_subcarriers=24,
                      local_steps=4, lr=0.05, compress=False)
    params, hist = run_fl(key, params, loss_fn, client_batch, fl_cfg)
    return cfg, params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--rhos", type=float, nargs="+",
                    default=[0.15, 0.3, 0.5, 0.75, 1.0])
    args = ap.parse_args()
    key = jax.random.PRNGKey(0)
    eval_batch = image_batch(jax.random.PRNGKey(99), 32)

    rows = []
    for rho in args.rhos:
        # each rho builds fresh jitted closures; XLA-CPU's ORC JIT can fail to
        # materialize symbols once too many dylibs accumulate in one process
        jax.clear_caches()
        cfg, params, hist = train_fedsem(args.rounds, rho, key)
        acc = float(proxy_accuracy(params, cfg, eval_batch))
        rows.append({
            "rho": rho,
            "final_mse": hist[-1].loss,
            "psnr_db": float(psnr(params, cfg, eval_batch)),
            "proxy_accuracy": acc,
            "fl_energy_total_J": sum(h.energy for h in hist),
            "fl_time_total_s": sum(h.t_fl for h in hist),
        })
        print(f"rho={rho:.2f}  mse={rows[-1]['final_mse']:.4f}  "
              f"psnr={rows[-1]['psnr_db']:.2f} dB  acc~{acc:.3f}  "
              f"E={rows[-1]['fl_energy_total_J']:.2f} J")

    fit = fit_power_law(
        jnp.asarray([r["rho"] for r in rows]),
        jnp.asarray([max(r["proxy_accuracy"], 1e-3) for r in rows]),
    )
    print(f"\nre-fitted A(rho) = {float(fit.a):.4f} * rho^{float(fit.b):.4f} "
          f"(paper: 0.6356 * rho^0.4025)")

    OUT.mkdir(parents=True, exist_ok=True)
    with open(OUT / "ae_accuracy.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]) )
        w.writeheader()
        w.writerows(rows)
    with open(OUT / "ae_accuracy_fit.csv", "w") as f:
        f.write(f"a,b\n{float(fit.a)},{float(fit.b)}\n")
    # Assumption 1 check: increasing in rho
    accs = [r["proxy_accuracy"] for r in rows]
    print("accuracy non-decreasing in rho:",
          all(accs[i + 1] >= accs[i] - 0.05 for i in range(len(accs) - 1)))


if __name__ == "__main__":
    main()
