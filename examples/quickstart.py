"""Quickstart: solve one FedSem resource-allocation scenario and compare
against the paper's four baselines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import AllocatorConfig, Weights, sample_params, solve
from repro.core import baselines as B
from repro.core.system import feasible, report


def main():
    key = jax.random.PRNGKey(0)
    params = sample_params(key)          # paper Table-I defaults: N=10, K=50
    w = Weights.ones()

    res = solve(params, w, AllocatorConfig(inner="sca"))   # Alg. A2
    rows = {"proposed (Alg. A2)": report(params, w, res.alloc)}
    rows["equal"] = report(params, w, B.equal_allocation(params))
    rows["comm-only"] = report(params, w, B.comm_opt_only(params, w, key))
    rows["comp-only"] = report(params, w, B.comp_opt_only(params, w))
    rows["random"] = report(params, w, B.random_allocation(params, key))

    print(f"{'method':22s} {'objective':>10s} {'energy J':>9s} {'T_FL s':>8s} {'rho':>5s}")
    for name, r in rows.items():
        print(f"{name:22s} {float(r['objective']):10.3f} "
              f"{float(r['energy_total']):9.3f} {float(r['t_fl']):8.3f} "
              f"{float(r['rho']):5.2f}")
    print("\nallocation feasible:", bool(feasible(params, res.alloc)))
    print("objective trace (Alg. A2 iters):",
          [round(float(x), 3) for x in res.trace])
    print("subcarriers per device:",
          jnp.sum(res.alloc.X, axis=1).astype(int).tolist())


if __name__ == "__main__":
    main()
