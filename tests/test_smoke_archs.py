"""Per-architecture smoke tests (reduced variants): one forward/train step on
CPU asserting output shapes + no NaNs, plus one decode step where the arch
supports decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.models import model as M
from repro.models.config import smoke_variant

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    b = {}
    if cfg.frontend == "audio":
        b["frame_embeds"] = jax.random.normal(key, (BATCH, SEQ, cfg.frontend_dim), jnp.float32)
        b["labels"] = jax.random.randint(key, (BATCH, SEQ), 0, cfg.n_classes)
        b["mask"] = jnp.ones((BATCH, SEQ), bool)
        return b
    toks = jax.random.randint(key, (BATCH, SEQ + 1), 0, cfg.vocab)
    b["tokens"] = toks[:, :-1]
    b["labels"] = toks[:, 1:]
    if cfg.frontend == "vision":
        n_patch = SEQ // 4
        b["patch_embeds"] = jax.random.normal(
            key, (BATCH, n_patch, cfg.frontend_dim), jnp.float32
        )
        b["labels"] = b["labels"].at[:, :n_patch].set(-1)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_grad(arch):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(lambda p, b: M.forward(p, cfg, b))(params, batch)
    out_dim = cfg.n_classes if cfg.arch_type == "audio" else cfg.vocab
    assert logits.shape == (BATCH, SEQ, out_dim)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))
    )(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize(
    "arch", [a for a in list_archs() if a != "hubert_xlarge"]
)
def test_smoke_decode(arch):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    cache = M.init_cache(cfg, BATCH, max_len=16)
    tok = jnp.zeros((BATCH, 1), jnp.int32)

    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c))
    for pos in range(3):
        logits, cache = step(params, tok, jnp.int32(pos), cache)
        assert logits.shape == (BATCH, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, :, :], -1).astype(jnp.int32)


def test_one_train_step_reduces_loss():
    """A few SGD steps on the qwen smoke variant reduce CE on a fixed batch."""
    cfg = smoke_variant(get_config("qwen2_5_3b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    from repro.optim.optimizers import adamw

    init, update = adamw(3e-3)
    state = init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
        params, state = update(g, state, params)
        return params, state, loss

    losses = []
    for _ in range(8):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
