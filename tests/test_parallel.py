"""Distribution-layer tests: sharding rules, mesh adaptation, HLO cost parser,
and a miniature end-to-end pjit dry-run on a 4-device host mesh."""
import os

# must run before jax import in this process (pytest collects this module
# first only if no other test already initialised jax — keep the count tiny
# and fall back gracefully if the backend is already locked)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_cost
from repro.launch.specs import SHAPES, mesh_adapt, shape_skip_reason
from repro.configs.registry import get_config
from repro.models.config import smoke_variant
from repro.parallel import sharding as SH


def _mesh_or_skip(shape, names):
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        pytest.skip(f"need {n} host devices")
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), names
    )


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_structure():
    cfg = smoke_variant(get_config("qwen2_5_3b"))
    from repro.models import model as M

    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    specs = SH.param_specs(params)
    b0 = specs["stages"]["main"][f"b0"]
    assert tuple(b0["attn"]["wq"]) == (None, None, "model", None)  # stacked
    # vocab-sharded embed (tied heads produce vocab-sharded logits, §Perf)
    assert tuple(specs["embed"]) == ("model", None)
    assert tuple(b0["ffn"]["w_down"]) == (None, "model", None)
    assert tuple(b0["ln"]) == (None,)   # stacked period dim, replicated


def test_sanitize_specs_drops_nondivisible():
    mesh = _mesh_or_skip((2, 2), ("data", "model"))
    specs = {"w": P(None, "model")}
    tree = {"w": jax.ShapeDtypeStruct((4, 7), jnp.float32)}  # 7 % 2 != 0
    out = SH.sanitize_specs(mesh, specs, tree)
    assert tuple(out["w"]) == (None, None)
    tree2 = {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    assert tuple(SH.sanitize_specs(mesh, specs, tree2)["w"]) == (None, "model")


@pytest.mark.parametrize("arch,ms,exp_h,exp_kv", [
    ("arctic_480b", 16, 64, 16),      # 56 -> 64 padded, kv 8 -> 16
    ("gemma2_2b", 16, 16, 16),        # 8 -> 16, kv 4 -> 16
    ("qwen2_5_3b", 16, 16, 16),       # kv 2 -> 16
    ("hubert_xlarge", 16, 16, 16),    # already divisible
    ("deepseek_v3_671b", 16, 128, 128),  # MLA untouched
])
def test_mesh_adapt_heads(arch, ms, exp_h, exp_kv):
    cfg = mesh_adapt(get_config(arch), ms)
    assert cfg.n_heads == exp_h and cfg.n_kv_heads == exp_kv
    assert cfg.n_heads % ms == 0 or cfg.use_mla


def test_shape_skips():
    assert shape_skip_reason(get_config("hubert_xlarge"), "decode_32k")
    assert shape_skip_reason(get_config("arctic_480b"), "long_500k")
    assert shape_skip_reason(get_config("gemma2_9b"), "long_500k") is None
    assert shape_skip_reason(get_config("qwen2_5_3b"), "long_500k") is None  # SWA variant
    assert shape_skip_reason(get_config("rwkv6_1_6b"), "long_500k") is None


# ---------------------------------------------------------------------------
# HLO cost parser
# ---------------------------------------------------------------------------

def test_hlo_cost_counts_loop_flops():
    def f(a, ws):
        return jax.lax.scan(lambda c, w: (c @ w, ()), a, ws)[0]

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    txt = jax.jit(f).lower(a, ws).compile().as_text()
    res = hlo_cost.analyze(txt)
    np.testing.assert_allclose(res["flops"], 7 * 2 * 256**3, rtol=0.05)


def test_hlo_cost_counts_collectives():
    mesh = _mesh_or_skip((4,), ("d",))
    xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    ns = lambda s: jax.sharding.NamedSharding(mesh, s)
    c = jax.jit(
        lambda x, w: x @ w,
        in_shardings=(ns(P(None, "d")), ns(P("d", None))),
    ).lower(xs, ws).compile()
    res = hlo_cost.analyze(c.as_text())
    # all-reduce of the (64,128) f32 result, weighted 2x
    np.testing.assert_allclose(res["collective_bytes"], 2 * 64 * 128 * 4, rtol=0.01)
    assert res["collective_counts"].get("all-reduce", 0) >= 1


# ---------------------------------------------------------------------------
# mini end-to-end pjit on a 2x2 host mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2_5_3b", "jamba_1_5_large_398b"])
def test_mini_pjit_train_step(arch):
    mesh = _mesh_or_skip((2, 2), ("data", "model"))
    cfg = smoke_variant(get_config(arch))
    if cfg.n_experts:
        cfg = cfg.scaled(n_experts=4, top_k=2)   # 4 experts over model=2
    from repro.launch.train import TrainState, build_train_step, init_state
    from repro.models import model as M

    state = init_state(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    pspecs = SH.sanitize_specs(mesh, SH.param_specs(state.params), state.params)
    ospecs = SH.opt_state_specs(state.opt, state.params)
    ospecs = type(ospecs)(
        step=ospecs.step,
        mu=SH.sanitize_specs(mesh, ospecs.mu, state.params),
        nu=SH.sanitize_specs(mesh, ospecs.nu, state.params),
    )
    ns = lambda t: jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), t)
    step = jax.jit(
        build_train_step(cfg, mesh=mesh),
        in_shardings=(
            TrainState(ns(pspecs), ns(ospecs)),
            ns(SH.batch_specs(mesh, batch)),
        ),
    )
    with mesh:
        state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree.map(lambda a, b: a - b, state2.params, state.params), 0.0,
    )
    assert delta > 0
