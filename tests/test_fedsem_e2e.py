"""The closed FedSem loop (`repro.fl.semcom_job`) and the asyncio driver
facade (`repro.serve.aio`): the autoencoder trains under served allocations,
the A(rho) refit reaches the service, and the async facade answers exactly
like the sync driver."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AllocatorConfig, Weights
from repro.core.pgd import PGDConfig
from repro.fl import (
    FLConfig,
    PlannedBackend,
    SemComJob,
    SemComJobConfig,
    ServiceBackend,
    sample_round_scenarios,
    serve_config_for,
)
from repro.semcom import AEConfig
from repro.serve import AllocService, AsyncAllocDriver, BatchPolicy, RealClockDriver

ALLOC = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
SERVE = serve_config_for(ALLOC, policy=BatchPolicy(max_batch=2, max_wait_s=0.01))
JOB = SemComJobConfig(
    fl=FLConfig(n_clients=3, n_subcarriers=8, rounds=2, local_steps=2),
    ae=AEConfig(image_size=16, hidden=4, base_latent=4),
    batch_size=4,
    eval_batch=8,
    refit_after=2,
)


@pytest.fixture(scope="module")
def executables():
    return {}


def test_semcom_job_closes_the_loop(executables):
    """AE trained by `run_fl` with served allocations: per-round rho drives
    the codec, measurements accumulate, and the refit lands in the service."""
    service = AllocService(SERVE, executables=executables)
    job = SemComJob(JOB)
    res = job.run(jax.random.PRNGKey(0), ServiceBackend(service))

    assert len(res.history) == JOB.fl.rounds
    for h in res.history:
        assert np.isfinite(h.loss) and 0.0 < h.rho <= 1.0
        assert h.energy > 0.0 and h.t_fl > 0.0
    # each round measures the solved rho plus every probe rho
    assert len(res.measurements) == JOB.fl.rounds * (1 + len(JOB.probe_rhos))
    assert all(0.0 <= a <= 1.0 for _, a in res.measurements)
    # the feedback edge: a fit exists, was pushed, and the service holds it
    assert res.accuracy_fit is not None
    assert res.refit_applied and res.refit_round is not None
    assert service._acc is res.accuracy_fit
    # Assumption 1 survives the refit: monotone nondecreasing on a grid
    vals = np.asarray(res.accuracy_fit.value(jnp.linspace(0.05, 1.0, 16)))
    assert np.all(np.diff(vals) >= -1e-7)


def test_semcom_job_planned_backend_declines_feedback():
    job = SemComJob(JOB)
    res = job.run(jax.random.PRNGKey(0), PlannedBackend(ALLOC))
    assert len(res.history) == JOB.fl.rounds
    assert res.accuracy_fit is not None      # measured and fit all the same
    assert res.refit_applied is False        # but the plan was already solved
    assert res.refit_round is None


def test_semcom_job_feedback_off_never_pushes(executables):
    service = AllocService(SERVE, executables=executables)
    default_acc = service._acc
    job = SemComJob(JOB._replace(feedback=False))
    res = job.run(jax.random.PRNGKey(0), ServiceBackend(service))
    assert res.refit_applied is False
    assert service._acc is default_acc


def test_async_facade_matches_sync_driver(executables):
    """`AsyncAllocDriver` answers request-for-request exactly like the sync
    driver path (it adds IO plumbing, no policy), and its context manager
    starts/drains the underlying driver."""
    fl = JOB.fl
    scenarios = sample_round_scenarios(jax.random.PRNGKey(9), fl, 1e4)

    service = AllocService(SERVE, executables=executables)
    service.warmup(scenarios)
    with RealClockDriver(service) as driver:
        sync_alloc = [
            driver.submit(p, Weights.ones()).result(timeout=120.0).alloc
            for p in scenarios
        ]

    async def go():
        svc = AllocService(SERVE, executables=executables)
        async with AsyncAllocDriver(svc) as facade:
            out = []
            for p in scenarios:
                c = await facade.submit(p, Weights.ones())
                out.append(c.alloc)
            return out, facade

    async_alloc, facade = asyncio.run(go())
    assert facade.driver._closed.is_set()     # __aexit__ drained the driver
    for a, b in zip(sync_alloc, async_alloc):
        np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))


def test_async_facade_concurrent_submits(executables):
    """Concurrent coroutines co-batch through one facade and all complete."""
    fl = JOB.fl
    scenarios = sample_round_scenarios(jax.random.PRNGKey(11), fl, 1e4)
    service = AllocService(SERVE, executables=executables)
    service.warmup(scenarios)

    async def go():
        async with AsyncAllocDriver(service) as facade:
            outs = await asyncio.gather(
                *(facade.submit(p) for p in scenarios)
            )
        return outs

    outs = asyncio.run(go())
    assert len(outs) == len(scenarios)
    assert sorted(c.req_id for c in outs) == list(range(len(scenarios)))
