"""Substrate tests: autoencoder, data pipeline, optimizers, checkpointing, FL."""
import os
import tempfile

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import restore, save
from repro.data.synthetic import (
    image_batch, make_bigram_table, partition_clients, token_batch,
)
from repro.optim.optimizers import (
    adamw, clip_by_global_norm, cosine_schedule, global_norm, sgd,
)
from repro.semcom.autoencoder import (
    AEConfig, forward, init_params, mse_loss, param_bits, proxy_accuracy, psnr,
)


# ---------------------------------------------------------------------------
# autoencoder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho", [0.15, 0.4, 0.75, 1.0])
def test_autoencoder_shapes_and_bits(rho):
    cfg = AEConfig(rho=rho)
    p = init_params(jax.random.PRNGKey(0), cfg)
    x = image_batch(jax.random.PRNGKey(1), 4)
    y = forward(p, cfg, x, jax.random.PRNGKey(2))
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # compressed payload grows with rho
    if rho < 1.0:
        assert cfg.compressed_bits <= AEConfig(rho=1.0).compressed_bits


def test_autoencoder_trains():
    cfg = AEConfig(rho=1.0, hidden=8, base_latent=4)
    p = init_params(jax.random.PRNGKey(0), cfg)
    x = image_batch(jax.random.PRNGKey(1), 16)
    init, update = adamw(3e-3)
    state = init(p)

    @jax.jit
    def step(p, s, k):
        loss, g = jax.value_and_grad(lambda q: mse_loss(q, cfg, x, k))(p)
        p, s = update(g, s, p)
        return p, s, loss

    losses = []
    for i in range(30):
        p, state, loss = step(p, state, jax.random.PRNGKey(i))
        losses.append(float(loss))
    assert losses[-1] < 0.7 * losses[0]
    assert float(psnr(p, cfg, x)) > 10.0
    assert 0.0 <= float(proxy_accuracy(p, cfg, x)) <= 1.0


def test_more_compression_worse_or_equal_reconstruction():
    """Assumption-1 direction: lower rho should not reconstruct better."""
    x = image_batch(jax.random.PRNGKey(1), 16)
    final = {}
    for rho in (0.25, 1.0):
        cfg = AEConfig(rho=rho, hidden=8)
        p = init_params(jax.random.PRNGKey(0), cfg)
        init, update = adamw(3e-3)
        state = init(p)
        step = jax.jit(lambda p, s, k: (lambda l, g: update(g, s, p) + (l,))(
            *jax.value_and_grad(lambda q: mse_loss(q, cfg, x, k))(p)))
        for i in range(40):
            p, state, _ = step(p, state, jax.random.PRNGKey(i))
        final[rho] = float(mse_loss(p, cfg, x))
    assert final[0.25] >= final[1.0] * 0.9


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_image_batch_deterministic():
    a = image_batch(jax.random.PRNGKey(3), 4)
    b = image_batch(jax.random.PRNGKey(3), 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(a.min()) >= -1.0 and float(a.max()) <= 1.0


def test_token_batch_in_vocab():
    table = make_bigram_table(jax.random.PRNGKey(0), 128)
    toks = token_batch(jax.random.PRNGKey(1), table, 4, 32)
    assert toks.shape == (4, 33)
    assert int(toks.min()) >= 0 and int(toks.max()) < 128


def test_partition_clients_sums():
    sizes = partition_clients(jax.random.PRNGKey(0), 8, pool=1024)
    assert len(sizes) == 8 and (sizes >= 16).all()


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(seed=st.integers(0, 1000))
def test_adamw_descends_quadratic(seed):
    target = jax.random.normal(jax.random.PRNGKey(seed), (8,))
    params = {"w": jnp.zeros((8,))}
    init, update = adamw(0.1)
    state = init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - target))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params)
    assert float(loss(params)) < 0.05


def test_sgd_momentum_matches_reference():
    params = {"w": jnp.ones((3,))}
    init, update = sgd(0.1, momentum=0.9)
    state = init(params)
    g = {"w": jnp.ones((3,))}
    p1, state = update(g, state, params)      # v=1, w=1-0.1
    p2, _ = update(g, state, p1)              # v=1.9, w=0.9-0.19
    np.testing.assert_allclose(np.asarray(p2["w"]), 0.9 - 0.19, rtol=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert float(lr(100)) < 1e-3


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, tree)
        out = restore(path, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, tree)
        with pytest.raises(ValueError):
            restore(path, {"a": jnp.ones((3, 2))})


# ---------------------------------------------------------------------------
# FL driver
# ---------------------------------------------------------------------------

def test_fl_round_reduces_loss_and_allocates():
    from repro.fl.federated import FLConfig, run_fl, topk_sparsify, tree_bits

    cfg = AEConfig(rho=1.0, hidden=8, base_latent=4)
    p = init_params(jax.random.PRNGKey(0), cfg)

    def loss_fn(q, batch, k):
        return mse_loss(q, cfg, batch, k)

    def client_batch(k, i):
        return image_batch(k, 4)

    eval_batch = image_batch(jax.random.PRNGKey(77), 16)
    loss_before = float(mse_loss(p, cfg, eval_batch))
    params, hist = run_fl(
        jax.random.PRNGKey(0), p, loss_fn, client_batch,
        FLConfig(rounds=4, n_clients=4, n_subcarriers=12, local_steps=3),
    )
    loss_after = float(mse_loss(params, cfg, eval_batch))
    assert loss_after < loss_before  # held-out eval improves
    for h in hist:
        assert h.energy > 0 and h.t_fl > 0 and 0 < h.rho <= 1.0


def test_topk_sparsify_keeps_fraction():
    from repro.fl.federated import topk_sparsify

    u = {"w": jnp.arange(100, dtype=jnp.float32) - 50.0}
    sp = topk_sparsify(u, 0.2)
    nz = int(jnp.sum(sp["w"] != 0))
    assert 15 <= nz <= 25
    # the largest-|.| entries survive
    assert float(sp["w"][0]) == -50.0 and float(sp["w"][99]) == 49.0
