"""Unit + property tests for the FedSem system model and Theorem-1 solver."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Weights, default_accuracy, sample_params
from repro.core.accuracy import AccuracyFn, fit_power_law
from repro.core.p3 import solve_T, solve_p3
from repro.core.system import (
    comp_energy,
    comp_time,
    device_power,
    device_rate,
    fl_tx_time,
    objective,
    subcarrier_rate,
)
from repro.core.types import Allocation

settings = hypothesis.settings(max_examples=25, deadline=None)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@pytest.fixture(scope="module")
def params():
    return sample_params(jax.random.PRNGKey(0))


@settings
@hypothesis.given(seed=seeds)
def test_rate_monotone_in_power(seed):
    params = sample_params(jax.random.PRNGKey(seed % 97), N=4, K=8)
    P1 = jnp.full((4, 8), 0.01)
    P2 = P1 * 2.0
    r1, r2 = subcarrier_rate(params, P1), subcarrier_rate(params, P2)
    assert bool(jnp.all(r2 >= r1))
    # concavity in power: midpoint rate >= chord
    rm = subcarrier_rate(params, 0.5 * (P1 + P2))
    assert bool(jnp.all(rm >= 0.5 * (r1 + r2) - 1e-3))


def test_units_sanity(params):
    """Paper-default scales: rates ~Mbps, tau ~ms, E_c ~0.01-0.2 J."""
    X = jnp.zeros((params.N, params.K)).at[jnp.arange(params.K) % params.N,
                                           jnp.arange(params.K)].set(1.0)
    P = X * 0.02
    r = device_rate(params, P, X)
    assert float(jnp.median(r)) > 1e6 and float(jnp.max(r)) < 1e9
    tau = fl_tx_time(params, r)
    assert float(jnp.max(tau)) < 1.0
    f = jnp.full((params.N,), 1e9)
    assert 1e-4 < float(jnp.sum(comp_energy(params, f))) < 1.0
    assert 0.01 < float(jnp.max(comp_time(params, f))) < 10.0


def test_accuracy_assumption1():
    """A(rho) increasing + concave (Assumption 1) for the default fit."""
    acc = default_accuracy()
    rho = jnp.linspace(0.01, 1.0, 101)
    v = acc.value(rho)
    assert bool(jnp.all(jnp.diff(v) > 0)), "increasing"
    assert bool(jnp.all(jnp.diff(jnp.diff(v)) < 1e-6)), "concave"
    np.testing.assert_allclose(float(acc.value(1.0)), 0.6356, rtol=1e-5)


def test_fit_power_law_roundtrip():
    acc = AccuracyFn(jnp.float32(0.7), jnp.float32(0.3))
    rho = jnp.linspace(0.05, 1.0, 20)
    fit = fit_power_law(rho, acc.value(rho))
    np.testing.assert_allclose(float(fit.a), 0.7, rtol=1e-3)
    np.testing.assert_allclose(float(fit.b), 0.3, rtol=1e-3)


@settings
@hypothesis.given(seed=seeds)
def test_theorem1_feasibility_and_kkt(seed):
    params = sample_params(jax.random.PRNGKey(seed % 89), N=5, K=10)
    w = Weights.ones()
    X = jnp.zeros((5, 10)).at[jnp.arange(10) % 5, jnp.arange(10)].set(1.0)
    P = X * 0.01
    sol = solve_p3(params, w, P, X)
    # primal feasibility
    assert bool(jnp.all(sol.f <= params.f_max * (1 + 1e-5)))
    assert 0.0 < float(sol.rho) <= 1.0
    r = device_rate(params, P, X)
    tau = fl_tx_time(params, r)
    # eq (30): T* = max(tau + t_c) exactly
    np.testing.assert_allclose(
        float(sol.T), float(jnp.max(tau + comp_time(params, sol.f))), rtol=1e-5
    )
    # SemCom deadline after the rho clip (13f)
    t_sc = sol.rho * params.C / jnp.maximum(r, 1e-9)
    assert bool(jnp.all(t_sc <= params.t_sc_max * (1 + 1e-4)))


def test_theorem1_rho_closed_form(params):
    """Bisection rho matches the analytic root of eq. (20) for power-law A."""
    w = Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(5.0))
    acc = default_accuracy()
    X = jnp.zeros((params.N, params.K)).at[jnp.arange(params.K) % params.N,
                                           jnp.arange(params.K)].set(1.0)
    P = X * params.p_max[:, None] / 5.0
    sol = solve_p3(params, w, P, X, acc)
    r = device_rate(params, P, X)
    cost = float(jnp.sum(w.kappa1 * device_power(P) * params.C / r))
    a, b = float(acc.a), float(acc.b)
    rho_analytic = (w.kappa3 * params.N * a * b / cost) ** (1.0 / (1.0 - b))
    rho_max = float(jnp.minimum(1.0, jnp.min(params.t_sc_max * r / params.C)))
    expected = min(min(float(rho_analytic), rho_max), 1.0)
    np.testing.assert_allclose(float(sol.rho), expected, rtol=1e-3)


def test_solve_T_stationarity(params):
    """Interior T satisfies eq. (28): sum 2 k1 xi f^3 = k2."""
    w = Weights.ones()
    X = jnp.zeros((params.N, params.K)).at[jnp.arange(params.K) % params.N,
                                           jnp.arange(params.K)].set(1.0)
    P = X * 0.02
    tau = fl_tx_time(params, device_rate(params, P, X))
    T = solve_T(params, w, tau)
    eta_cd = params.eta * params.c * params.d
    f = jnp.minimum(eta_cd / (T - tau), params.f_max)
    lhs = float(jnp.sum(2.0 * w.kappa1 * params.xi * f**3))
    t_lo = float(jnp.max(tau + eta_cd / params.f_max))
    if float(T) > t_lo * (1 + 1e-6):  # interior solution
        np.testing.assert_allclose(lhs, 1.0, rtol=1e-3)


def test_objective_weight_scaling(params):
    """kappa scaling acts linearly on the respective objective terms."""
    X = jnp.zeros((params.N, params.K)).at[jnp.arange(params.K) % params.N,
                                           jnp.arange(params.K)].set(1.0)
    alloc = Allocation(
        f=jnp.full((params.N,), 1e9), P=X * 0.01, X=X, rho=jnp.float32(0.5)
    )
    w1 = Weights.ones()
    w2 = Weights(jnp.float32(2.0), jnp.float32(1.0), jnp.float32(1.0))
    o1 = float(objective(params, w1, alloc))
    o2 = float(objective(params, w2, alloc))
    from repro.core.system import energy_breakdown

    e = float(sum(jnp.sum(x) for x in energy_breakdown(params, alloc)))
    np.testing.assert_allclose(o2 - o1, e, rtol=1e-4)
