"""Hypothesis property tests on FedSem system-model invariants (fast, pure)."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Weights, sample_params
from repro.core.allocator import harden_x
from repro.core.p3 import solve_T, solve_rho
from repro.core.accuracy import default_accuracy
from repro.core.system import (
    device_power, device_rate, fl_tx_time, semcom_energy, subcarrier_rate,
)

settings = hypothesis.settings(max_examples=20, deadline=None)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings
@hypothesis.given(seed=seeds)
def test_rate_scale_invariance_in_gain_power_product(seed):
    """r(p, g) depends on g only through p*g (SNR): r(2p, g) == r(p, 2g)."""
    params = sample_params(jax.random.PRNGKey(seed % 101), N=3, K=6)
    P = jnp.full((3, 6), 0.01)
    import dataclasses

    params2 = dataclasses.replace(params, g=params.g * 2.0)
    np.testing.assert_allclose(
        np.asarray(subcarrier_rate(params, 2 * P)),
        np.asarray(subcarrier_rate(params2, P)),
        rtol=1e-5,
    )


@settings
@hypothesis.given(seed=seeds)
def test_semcom_energy_linear_in_rho(seed):
    params = sample_params(jax.random.PRNGKey(seed % 103), N=3, K=6)
    X = jnp.zeros((3, 6)).at[jnp.arange(6) % 3, jnp.arange(6)].set(1.0)
    P = X * 0.01
    r = device_rate(params, P, X)
    p_n = device_power(P)
    e1 = semcom_energy(params, 0.3, p_n, r)
    e2 = semcom_energy(params, 0.6, p_n, r)
    np.testing.assert_allclose(np.asarray(e2), 2 * np.asarray(e1), rtol=1e-5)


@settings
@hypothesis.given(seed=seeds, k2a=st.floats(0.2, 1.0), k2b=st.floats(2.0, 10.0))
def test_T_monotone_decreasing_in_kappa2(seed, k2a, k2b):
    """Higher time weight => the chosen FL deadline T can only shrink."""
    params = sample_params(jax.random.PRNGKey(seed % 107), N=4, K=8)
    X = jnp.zeros((4, 8)).at[jnp.arange(8) % 4, jnp.arange(8)].set(1.0)
    tau = fl_tx_time(params, device_rate(params, X * 0.01, X))
    Ta = solve_T(params, Weights(jnp.float32(1.0), jnp.float32(k2a), jnp.float32(1.0)), tau)
    Tb = solve_T(params, Weights(jnp.float32(1.0), jnp.float32(k2b), jnp.float32(1.0)), tau)
    assert float(Tb) <= float(Ta) * (1 + 1e-4)


@settings
@hypothesis.given(seed=seeds, k3a=st.floats(0.01, 0.5), k3b=st.floats(2.0, 20.0))
def test_rho_monotone_in_kappa3(seed, k3a, k3b):
    """Theorem-1 rho* is non-decreasing in the accuracy weight kappa3."""
    params = sample_params(jax.random.PRNGKey(seed % 109), N=4, K=8)
    X = jnp.zeros((4, 8)).at[jnp.arange(8) % 4, jnp.arange(8)].set(1.0)
    P = X * 0.01
    r = device_rate(params, P, X)
    p_n = device_power(P)
    acc = default_accuracy()
    ra = solve_rho(params, Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(k3a)), r, p_n, acc)
    rb = solve_rho(params, Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(k3b)), r, p_n, acc)
    assert float(rb) >= float(ra) - 1e-5


@settings
@hypothesis.given(seed=seeds)
def test_harden_x_valid_assignment(seed):
    """Hardening any soft X yields: binary, <=1 device per subcarrier,
    >=1 subcarrier per device, and is idempotent."""
    N, K = 4, 10
    X = jax.random.uniform(jax.random.PRNGKey(seed % 113), (N, K))
    Xb = harden_x(X, N, K)
    arr = np.asarray(Xb)
    assert set(np.unique(arr)).issubset({0.0, 1.0})
    assert (arr.sum(0) <= 1).all()
    assert (arr.sum(1) >= 1).all()
    np.testing.assert_array_equal(np.asarray(harden_x(Xb, N, K)), arr)


@settings
@hypothesis.given(seed=seeds)
def test_topk_update_compression_bounds(seed):
    """rho-compression keeps <= ~rho fraction of entries and preserves the
    largest-magnitude ones (paper's rho = transmitted/original semantics)."""
    from repro.fl.federated import topk_sparsify

    u = {"w": jax.random.normal(jax.random.PRNGKey(seed % 127), (400,))}
    rho = 0.25
    sp = topk_sparsify(u, rho)
    nz = int(jnp.sum(sp["w"] != 0))
    assert nz <= int(400 * rho * 1.2) + 1
    kept_min = float(jnp.min(jnp.abs(sp["w"][sp["w"] != 0]))) if nz else 0.0
    dropped_max = float(jnp.max(jnp.abs(jnp.where(sp["w"] == 0, u["w"], 0.0))))
    assert kept_min >= dropped_max - 1e-6


def test_hypothesis_fallback_never_shadows_loaded_engine():
    """conftest prefers the real hypothesis package and installs the shim only
    when the import fails; the installer itself must also be a no-op when an
    engine (real or shim) is already loaded or installed, so no call order can
    shadow the real package (ROADMAP item: the shim has no shrinking)."""
    import sys

    from repro.testing import install_hypothesis_fallback

    engine = sys.modules["hypothesis"]
    install_hypothesis_fallback()
    assert sys.modules["hypothesis"] is engine
