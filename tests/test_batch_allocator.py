"""Batched multi-scenario engine: solve_batch == per-scenario solve,
feasibility of every batched allocation, stacking/validation edge cases, and
the FL driver's pre-planned allocations vs the sequential path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AllocatorConfig,
    Weights,
    sample_params,
    sample_params_batch,
    solve,
    solve_batch,
    stack_params,
    tree_index,
)
from repro.core.system import feasible

CFG = AllocatorConfig(inner="pgd")
W = Weights.ones()
B = 4


@pytest.fixture(scope="module")
def scenarios():
    return [sample_params(jax.random.PRNGKey(i), N=4, K=12) for i in range(B)]


@pytest.fixture(scope="module")
def batch_result(scenarios):
    return solve_batch(stack_params(scenarios), W, CFG)


def test_solve_batch_shapes(scenarios, batch_result):
    assert batch_result.alloc.P.shape == (B, 4, 12)
    assert batch_result.alloc.X.shape == (B, 4, 12)
    assert batch_result.alloc.f.shape == (B, 4)
    assert batch_result.alloc.rho.shape == (B,)
    assert batch_result.trace.shape[0] == B


def test_solve_batch_matches_sequential(scenarios, batch_result):
    """vmapped Alg. A2 == per-scenario solve: same hardened X, same trace."""
    solve_jit = jax.jit(lambda p: solve(p, W, CFG))
    for i, params in enumerate(scenarios):
        ref = solve_jit(params)
        got = tree_index(batch_result, i)
        np.testing.assert_array_equal(np.asarray(got.alloc.X), np.asarray(ref.alloc.X))
        np.testing.assert_allclose(
            np.asarray(got.alloc.P), np.asarray(ref.alloc.P), rtol=1e-4, atol=1e-9
        )
        np.testing.assert_allclose(
            np.asarray(got.alloc.f), np.asarray(ref.alloc.f), rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(got.alloc.rho), np.asarray(ref.alloc.rho), rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(got.trace), np.asarray(ref.trace), rtol=1e-3
        )


def test_solve_batch_all_feasible(scenarios, batch_result):
    for i, params in enumerate(scenarios):
        alloc = tree_index(batch_result.alloc, i)
        assert bool(feasible(params, alloc)), f"scenario {i} infeasible"
        assert np.isfinite(np.asarray(batch_result.trace[i])).all()


def test_sample_params_batch_stacks():
    pb = sample_params_batch(jax.random.PRNGKey(0), 3, N=4, K=12)
    assert pb.g.shape == (3, 4, 12)
    assert pb.p_max.shape == (3, 4)
    assert pb.N == 4 and pb.K == 12  # meta stays scalar
    # scenarios are distinct draws
    assert float(jnp.max(jnp.abs(pb.g[0] - pb.g[1]))) > 0


def test_stack_tree_index_roundtrip(scenarios):
    """tree_index(stack_params(xs), i) == xs[i], leaf for leaf (incl. masks)."""
    pb = stack_params(scenarios)
    for i, p in enumerate(scenarios):
        got = tree_index(pb, i)
        got_leaves, got_def = jax.tree.flatten(got)
        ref_leaves, ref_def = jax.tree.flatten(p)
        assert got_def == ref_def
        for a, b in zip(got_leaves, ref_leaves):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert (got.N, got.K, got.B) == (p.N, p.K, p.B)


def test_stack_params_rejects_meta_mismatch():
    a = sample_params(jax.random.PRNGKey(0), N=4, K=12)
    b = sample_params(jax.random.PRNGKey(1), N=4, K=16)
    with pytest.raises(ValueError, match="static"):
        stack_params([a, b])


def test_stack_params_rejects_empty():
    with pytest.raises(ValueError):
        stack_params([])


def test_solve_batch_rejects_unbatched():
    params = sample_params(jax.random.PRNGKey(0), N=4, K=12)
    with pytest.raises(ValueError, match="batch-stacked"):
        solve_batch(params, W, CFG)


def test_k_less_than_n_rejected():
    """Regression: N > K used to leave devices without subcarriers
    (`equal_start` round-robin + `harden_x` can't fix it); now it's a clear
    constructor error."""
    with pytest.raises(ValueError, match="K >= N"):
        sample_params(jax.random.PRNGKey(0), N=8, K=4)


def test_fl_plan_matches_sequential_solve():
    """The FL driver's one-shot batched plan == the seed's per-round solve."""
    from repro.fl.federated import FLConfig, plan_allocations, round_channel_key

    cfg = FLConfig(n_clients=3, n_subcarriers=6, rounds=3)
    d_bits = 1.0e4
    w = Weights.ones()
    sys_batch, res = plan_allocations(jax.random.PRNGKey(5), cfg, d_bits, w)
    assert sys_batch.g.shape == (cfg.rounds, 3, 6)

    solve_jit = jax.jit(
        lambda p: solve(p, w, AllocatorConfig(inner=cfg.allocator_inner))
    )
    for rnd in range(cfg.rounds):
        params = sample_params(
            round_channel_key(jax.random.PRNGKey(5), rnd),
            N=cfg.n_clients,
            K=cfg.n_subcarriers,
            D_bits=d_bits,
        )
        np.testing.assert_array_equal(
            np.asarray(tree_index(sys_batch, rnd).g), np.asarray(params.g)
        )
        ref = solve_jit(params)
        np.testing.assert_array_equal(
            np.asarray(tree_index(res.alloc.X, rnd)), np.asarray(ref.alloc.X)
        )
        np.testing.assert_allclose(
            np.asarray(tree_index(res.alloc.rho, rnd)),
            np.asarray(ref.alloc.rho),
            rtol=1e-4,
        )
        assert bool(feasible(params, tree_index(res.alloc, rnd)))
