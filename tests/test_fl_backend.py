"""The allocation-backend split (`repro.fl.alloc_backend`): PlannedBackend
preserves the offline path bit-for-bit, ServiceBackend over the serving stack
returns the SAME hardened assignments (the new equivalence-table row), and
`run_fl` is backend-agnostic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AllocatorConfig, Weights, tree_index
from repro.core.pgd import PGDConfig
from repro.fl import (
    FLConfig,
    PlannedBackend,
    ServiceBackend,
    plan_allocations,
    run_fl,
    sample_round_scenarios,
    serve_config_for,
)
from repro.serve import AllocService, AsyncAllocDriver, BatchPolicy, RealClockDriver

ALLOC = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
FL = FLConfig(n_clients=3, n_subcarriers=8, rounds=2, allocator_inner="pgd")
SERVE = serve_config_for(ALLOC, policy=BatchPolicy(max_batch=2, max_wait_s=0.01))
D_BITS = 1e4


@pytest.fixture(scope="module")
def scenarios():
    return sample_round_scenarios(jax.random.PRNGKey(3), FL, D_BITS)


@pytest.fixture(scope="module")
def planned(scenarios):
    b = PlannedBackend(ALLOC)
    b.open(scenarios, Weights.ones())
    return b


@pytest.fixture(scope="module")
def executables():
    """One compiled-solver cache for every service in this module (the cache
    key pins allocator + bucket + slots, so sharing is safe)."""
    return {}


def test_planned_backend_is_the_offline_plan(scenarios):
    """`plan_allocations` (regression-pinned against sequential `solve` in
    test_batch_allocator) and `PlannedBackend` are the same computation —
    same full allocator config, same samples, bit-identical plan."""
    planned = PlannedBackend(AllocatorConfig(inner=FL.allocator_inner))
    planned.open(scenarios, Weights.ones())
    sys_batch, res = plan_allocations(
        jax.random.PRNGKey(3), FL, D_BITS, Weights.ones()
    )
    np.testing.assert_array_equal(np.asarray(sys_batch.g), np.asarray(planned.sys_batch.g))
    for rnd in range(FL.rounds):
        a, b = tree_index(res.alloc, rnd), planned.allocate(rnd)
        np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))
        np.testing.assert_array_equal(np.asarray(a.rho), np.asarray(b.rho))


def _assert_matches_planned(backend, scenarios, planned):
    backend.open(scenarios, Weights.ones())
    for rnd in range(FL.rounds):
        a, b = planned.allocate(rnd), backend.allocate(rnd)
        np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))
        assert np.allclose(float(a.rho), float(b.rho), atol=1e-6)


def test_service_backend_virtual_matches_planned(scenarios, planned, executables):
    """THE new equivalence row: ServiceBackend over the virtual-clock service
    == PlannedBackend, exact hardened X per round."""
    service = AllocService(SERVE, executables=executables)
    _assert_matches_planned(ServiceBackend(service), scenarios, planned)


def test_service_backend_real_driver_matches_planned(scenarios, planned, executables):
    service = AllocService(SERVE, executables=executables)
    service.warmup(scenarios)
    with RealClockDriver(service) as driver:
        _assert_matches_planned(ServiceBackend(driver), scenarios, planned)


def test_service_backend_unwraps_async_facade(executables):
    service = AllocService(SERVE, executables=executables)
    facade = AsyncAllocDriver(service)          # not started; unwrap only
    backend = ServiceBackend(facade)
    assert backend._driver is facade.driver
    facade.driver.close()


def test_service_backend_rejects_unknown_target():
    with pytest.raises(TypeError):
        ServiceBackend(object())


def test_accuracy_feedback_contract(scenarios, executables):
    """PlannedBackend declines a refit (it solved everything up front);
    ServiceBackend accepts and the service's A(rho) actually changes."""
    from repro.core import AccuracyFn

    fit = AccuracyFn(jnp.float32(0.5), jnp.float32(0.3))
    planned = PlannedBackend(ALLOC)
    assert planned.supports_accuracy_feedback is False
    assert planned.set_accuracy(fit) is False

    service = AllocService(SERVE, executables=executables)
    backend = ServiceBackend(service)
    assert backend.supports_accuracy_feedback is True
    assert backend.set_accuracy(fit) is True
    assert service._acc is fit


def test_tenant_refit_does_not_touch_cotenant_rounds(scenarios, planned, executables):
    """Two-job interference regression: job B pushing an aggressive A(rho)
    refit between job A's rounds must not change job A's remaining rounds
    bit-for-bit (per-tenant registry — B's belief never reaches A's rows).
    Job A runs under the DEFAULT fit, so its rounds must also stay identical
    to the planned solve."""
    from repro.core import AccuracyFn

    service = AllocService(SERVE, executables=executables)
    a = ServiceBackend(service, tenant="job-a")
    b = ServiceBackend(service, tenant="job-b")
    a.open(scenarios, Weights.ones())
    b.open(scenarios, Weights.ones())

    for rnd in range(FL.rounds):
        before = a.allocate(rnd)
        # B refits hard between A's rounds — steep, low-ceiling curve
        assert b.set_accuracy(AccuracyFn(jnp.float32(0.2), jnp.float32(0.9)))
        after = a.allocate(rnd)          # same scenario, re-submitted
        ref = planned.allocate(rnd)
        np.testing.assert_array_equal(np.asarray(before.X), np.asarray(after.X))
        np.testing.assert_array_equal(
            np.asarray(before.rho), np.asarray(after.rho)
        )
        np.testing.assert_array_equal(np.asarray(after.X), np.asarray(ref.X))
        # B's own rounds DO see its refit: its request signature-level fit
        # differs, so its allocation may legitimately diverge from planned —
        # only assert it still returns a hardened assignment
        xb = np.asarray(b.allocate(rnd).X)
        assert set(np.unique(xb)) <= {0.0, 1.0}


def test_global_set_accuracy_still_reaches_unregistered_tenants(executables):
    """Compatibility shim: `set_accuracy` without a tenant swaps the
    all-tenants default, and requests with no tenant (or an unregistered
    one) are stamped with it — the legacy service-global behaviour."""
    from repro.core import AccuracyFn

    service = AllocService(SERVE, executables=executables)
    fit = AccuracyFn(jnp.float32(0.5), jnp.float32(0.3))
    service.set_accuracy(fit)
    assert service._resolve_accuracy() is fit
    assert service._resolve_accuracy(tenant="never-registered") is fit
    own = AccuracyFn(jnp.float32(0.7), jnp.float32(0.2))
    service.set_accuracy(own, tenant="job-x")
    assert service._resolve_accuracy(tenant="job-x") is own
    assert service._resolve_accuracy(tenant="job-y") is fit
    explicit = AccuracyFn(jnp.float32(0.9), jnp.float32(0.1))
    assert service._resolve_accuracy(explicit, tenant="job-x") is explicit


def test_run_fl_backend_agnostic(executables):
    """Identical histories through the default (planned) path and a
    ServiceBackend: routing the FL loop through the serving stack changes
    scheduling, never training."""
    cfg = FL._replace(rounds=2)
    p0 = {"w": jnp.zeros((4,))}

    def loss_fn(p, batch, k):
        return jnp.mean(jnp.square(p["w"] - batch))

    def client_batch(k, i):
        return jax.random.normal(k, (4,))

    def go(backend):
        return run_fl(
            jax.random.PRNGKey(5), p0, loss_fn, client_batch, cfg,
            backend=backend,
        )

    p_planned, h_planned = go(PlannedBackend(ALLOC))
    p_served, h_served = go(
        ServiceBackend(AllocService(SERVE, executables=executables))
    )
    for hp, hs in zip(h_planned, h_served):
        assert hp.rho == pytest.approx(hs.rho, abs=1e-6)
        assert hp.loss == pytest.approx(hs.loss, abs=1e-6)
        # energy reflects the solve's re-solved powers: hardened X is exact
        # across backends (asserted above) but P carries padded-solve drift,
        # amplified by the deliberately under-converged smoke allocator
        assert hp.energy == pytest.approx(hs.energy, rel=0.05)
    np.testing.assert_allclose(
        np.asarray(p_planned["w"]), np.asarray(p_served["w"]), atol=1e-6
    )


def test_round_hook_sees_every_round(executables):
    cfg = FL._replace(rounds=2)
    p0 = {"w": jnp.zeros((2,))}
    seen = []
    run_fl(
        jax.random.PRNGKey(5), p0,
        lambda p, b, k: jnp.mean(jnp.square(p["w"] - b)),
        lambda k, i: jax.random.normal(k, (2,)),
        cfg,
        backend=PlannedBackend(ALLOC),
        round_hook=lambda rnd, params, alloc, stats: seen.append(
            (rnd, float(alloc.rho), stats.loss)
        ),
    )
    assert [s[0] for s in seen] == [0, 1]
    assert all(0 < s[1] <= 1.0 for s in seen)


def test_service_backend_warm_rounds_dominates(scenarios, executables):
    """``warm_rounds=True`` chains each round's request to the previous
    round's hardened solution as an explicit warm start. The dominance
    baseline is the SAME service cold (warm_rounds off) — dominance is an
    invariant of one padded program, and the planned exact-shape solve
    carries fp-level padding drift that is outside its scope. Round 0 has no
    predecessor, so it is bit-for-bit the cold round 0."""
    from repro.core.accuracy import default_accuracy
    from repro.core.system import objective

    cold_backend = ServiceBackend(
        AllocService(SERVE, executables=executables), warm_rounds=False
    )
    warm_backend = ServiceBackend(
        AllocService(SERVE, executables=executables), warm_rounds=True
    )
    cold_backend.open(scenarios, Weights.ones())
    warm_backend.open(scenarios, Weights.ones())
    acc = default_accuracy()
    for rnd in range(FL.rounds):
        warm = warm_backend.allocate(rnd)
        cold = cold_backend.allocate(rnd)
        if rnd == 0:
            np.testing.assert_array_equal(np.asarray(warm.X), np.asarray(cold.X))
            np.testing.assert_array_equal(np.asarray(warm.f), np.asarray(cold.f))
        o_warm = float(objective(scenarios[rnd], Weights.ones(), warm, acc))
        o_cold = float(objective(scenarios[rnd], Weights.ones(), cold, acc))
        assert o_warm <= o_cold + 1e-5 * max(1.0, abs(o_cold))
        X = np.asarray(warm.X)
        assert set(np.unique(X)) <= {0.0, 1.0}
