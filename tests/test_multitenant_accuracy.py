"""Multi-tenant A(rho): per-request batched accuracy, asserted bit-for-bit.

The tentpole guarantees (docs/ARCHITECTURE.md equivalence table):

* UNIFORM stack == replicated scalar: `solve_batch(..., acc_batched=True)`
  over `stack_accuracy([fit] * B)` returns exactly what the legacy
  replicated-scalar program returns — every leaf, not just hardened X.
* MIXED stack == as-if-alone: a row co-batched with OTHER tenants' fits is
  bit-identical to the same row in a batch where every row carries its own
  fit. vmap rows are independent, so another tenant's belief can never leak
  into this tenant's answer.

Both are exercised at three layers — raw allocator (`solve_batch`), sans-IO
service (admission stamps the fit at `prepare`), and the threaded real-clock
driver (tenant registry) — with a hypothesis sweep over random per-row fits,
including identical-fit rows co-batched with distinct-fit rows (the dedup
temptation the design rejects: stamping per row keeps the program count at
one regardless of fit mix).

Plus the two service-lifecycle regressions that motivated the refactor:

* `_score_flush` race: a `set_accuracy` landing between admission and flush
  must not re-score in-flight completions — `Completion.objective` reflects
  the fit the request was STAMPED with, not the global at flush time.
* zero recompiles per refit: A(rho) is a runtime argument, so `set_accuracy`
  (global or per-tenant) never grows the executable cache.
"""
import hypothesis
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AllocatorConfig,
    Weights,
    sample_params,
    solve,
    solve_batch,
    stack_accuracy,
    stack_params,
    tree_index,
)
from repro.core.accuracy import AccuracyFn, default_accuracy
from repro.core.pgd import PGDConfig
from repro.core.system import feasible, objective
from repro.serve import AllocService, BatchPolicy, RealClockDriver, ServeConfig

SHIM = getattr(hypothesis, "__version__", "") == "0.0.0-fedsem-shim"
N_EXAMPLES = 40 if SHIM else 120

W = Weights.ones()
TINY = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=40))
SERVE = ServeConfig(policy=BatchPolicy(max_batch=2, max_wait_s=0.01), allocator=TINY)
#: one fixed shape across the whole module: every test and every hypothesis
#: example reuses the same compiled programs (shared executables fixture)
N, K = 3, 8
WAIT_S = 120.0


def fit(a: float, b: float) -> AccuracyFn:
    return AccuracyFn(jnp.float32(a), jnp.float32(b))


def params_for(seed: int):
    return sample_params(jax.random.PRNGKey(seed), N=N, K=K)


def assert_alloc_equal(x, y):
    """Bit-for-bit on every allocation leaf — the equivalences are exact."""
    for name in ("f", "P", "X", "rho"):
        np.testing.assert_array_equal(
            np.asarray(getattr(x, name)), np.asarray(getattr(y, name)), err_msg=name
        )


@pytest.fixture(scope="module")
def executables():
    return {}


# ---------------------------------------------------------------------------
# allocator layer
# ---------------------------------------------------------------------------


def test_uniform_stack_matches_replicated_scalar():
    """Equivalence row 1: stacking one fit B times and running the batched-acc
    program == broadcasting the scalar fit (the legacy program), exactly."""
    pb = stack_params([params_for(s) for s in (0, 1, 2)])
    acc = fit(0.6, 0.35)
    batched = solve_batch(pb, W, TINY, stack_accuracy([acc] * 3), acc_batched=True)
    scalar = solve_batch(pb, W, TINY, acc)
    assert_alloc_equal(batched.alloc, scalar.alloc)
    np.testing.assert_array_equal(
        np.asarray(batched.trace), np.asarray(scalar.trace)
    )


def test_mixed_stack_rows_as_if_alone():
    """Equivalence row 2: each co-batched row is bit-identical to the same row
    in a batch where EVERY row carries that row's fit — other tenants' fits
    cannot leak across vmap rows."""
    scenarios = [params_for(s) for s in (3, 4, 5)]
    fits = [fit(0.45, 0.55), fit(0.7, 0.2), fit(0.55, 0.45)]
    pb = stack_params(scenarios)
    mixed = solve_batch(pb, W, TINY, stack_accuracy(fits), acc_batched=True)
    for i, (p, f) in enumerate(zip(scenarios, fits)):
        alone = solve_batch(pb, W, TINY, stack_accuracy([f] * 3), acc_batched=True)
        assert_alloc_equal(
            tree_index(mixed.alloc, i), tree_index(alone.alloc, i)
        )
        # and the hardened assignment agrees with an unbatched solve under
        # that fit (fp-exact on the discrete decision, like the weights row)
        ref = jax.jit(lambda q, a: solve(q, W, TINY, a))(p, f)
        np.testing.assert_array_equal(
            np.asarray(tree_index(mixed.alloc.X, i)), np.asarray(ref.alloc.X)
        )
        assert bool(feasible(p, tree_index(mixed.alloc, i)))


def test_acc_batched_rejects_scalar_and_wrong_batch():
    pb = stack_params([params_for(0)] * 3)
    with pytest.raises(ValueError, match="leading batch axis"):
        solve_batch(pb, W, TINY, fit(0.5, 0.5), acc_batched=True)
    with pytest.raises(ValueError, match="size B=3"):
        solve_batch(pb, W, TINY, stack_accuracy([fit(0.5, 0.5)] * 2), acc_batched=True)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    a0=st.floats(min_value=0.3, max_value=0.9),
    b0=st.floats(min_value=0.1, max_value=0.9),
    a1=st.floats(min_value=0.3, max_value=0.9),
    b1=st.floats(min_value=0.1, max_value=0.9),
    dup=st.booleans(),
)
def test_prop_allocator_mixed_rows_as_if_alone(seed, a0, b0, a1, b1, dup):
    """Property: for random scenarios and random per-row fits — including a
    duplicated fit co-batched with a distinct one (``dup``) — every row of the
    mixed-acc solve equals its row in the own-fit-everywhere solve, exactly."""
    scenarios = [params_for(seed), params_for(seed + 1)]
    fits = [fit(a0, b0), fit(a0, b0) if dup else fit(a1, b1)]
    pb = stack_params(scenarios)
    mixed = solve_batch(pb, W, TINY, stack_accuracy(fits), acc_batched=True)
    for i, f in enumerate(fits):
        alone = solve_batch(pb, W, TINY, stack_accuracy([f] * 2), acc_batched=True)
        assert_alloc_equal(tree_index(mixed.alloc, i), tree_index(alone.alloc, i))


# ---------------------------------------------------------------------------
# service layer: stamping at prepare
# ---------------------------------------------------------------------------


def _solo_alloc(p, acc, executables):
    """What a tenant would get from a service all to itself."""
    service = AllocService(SERVE, executables=executables)
    service.submit(p, accuracy=acc, now=0.0)
    done, _ = service.drain(now=0.0)
    return done[0]


@settings(max_examples=max(10, N_EXAMPLES // 4), deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    a0=st.floats(min_value=0.3, max_value=0.9),
    b0=st.floats(min_value=0.1, max_value=0.9),
    a1=st.floats(min_value=0.3, max_value=0.9),
    b1=st.floats(min_value=0.1, max_value=0.9),
    dup=st.booleans(),
)
def test_prop_service_cobatch_as_if_alone(
    executables, seed, a0, b0, a1, b1, dup
):
    """Two tenants' requests co-batched by the micro-batcher each get the
    answer a solo service would give them — bit-for-bit, including the scored
    objective (the padded-batch scorer uses the same stamped per-row fits)."""
    p0, p1 = params_for(seed), params_for(seed + 1)
    f0 = fit(a0, b0)
    f1 = f0 if dup else fit(a1, b1)
    service = AllocService(SERVE, executables=executables)
    service.submit(p0, accuracy=f0, now=0.0)
    service.submit(p1, accuracy=f1, now=0.0)
    (c0, c1), _ = service.flush_full(now=0.0)
    for c, p, f in ((c0, p0, f0), (c1, p1, f1)):
        solo = _solo_alloc(p, f, executables)
        assert_alloc_equal(c.alloc, solo.alloc)
        assert c.objective == solo.objective
        # the scored objective is the eq. 13 value under the STAMPED fit
        ref = float(objective(p, W, c.alloc, f))
        assert c.objective == pytest.approx(ref, abs=1e-4 * max(1.0, abs(ref)))


def test_tenant_registry_stamps_at_prepare(executables):
    """Requests resolve explicit > tenant registry > global default, and the
    stamp happens at admission: a later registry update must not re-steer an
    already-queued request."""
    p = params_for(42)
    service = AllocService(SERVE, executables=executables)
    f_a, f_b = fit(0.7, 0.2), fit(0.4, 0.7)
    service.set_accuracy(f_a, tenant="a")
    service.submit(p, tenant="a", now=0.0)
    service.set_accuracy(f_b, tenant="a")      # lands AFTER admission
    service.submit(p, tenant="a", now=0.0)
    (c_old, c_new), _ = service.flush_full(now=0.0)
    assert_alloc_equal(c_old.alloc, _solo_alloc(p, f_a, executables).alloc)
    assert_alloc_equal(c_new.alloc, _solo_alloc(p, f_b, executables).alloc)


def test_score_flush_uses_stamped_fit_not_flush_time_global(executables):
    """THE `_score_flush` race regression: a global refit landing between
    admission and flush used to re-score the in-flight batch under the NEW
    model (solve and score disagreed). Both now read the request's stamp."""
    p = params_for(43)
    service = AllocService(SERVE, executables=executables)
    stamped = default_accuracy()
    service.submit(p, now=0.0)                 # stamped with the default
    service.set_accuracy(fit(0.2, 0.9))        # divergent refit mid-flight
    service.submit(p, now=0.0)                 # stamped with the refit
    (c_old, c_new), _ = service.flush_full(now=0.0)
    ref_old = float(objective(p, W, c_old.alloc, stamped))
    ref_new = float(objective(p, W, c_new.alloc, fit(0.2, 0.9)))
    assert c_old.objective == pytest.approx(
        ref_old, abs=1e-4 * max(1.0, abs(ref_old))
    )
    assert c_new.objective == pytest.approx(
        ref_new, abs=1e-4 * max(1.0, abs(ref_new))
    )
    # and the old request's answer is the pre-refit answer
    assert_alloc_equal(c_old.alloc, _solo_alloc(p, stamped, executables).alloc)


def test_refit_adds_zero_recompiles(executables):
    """A(rho) rides the batch as a runtime argument: refits — global or
    per-tenant, however many — never mint a new executable."""
    p = params_for(44)
    service = AllocService(SERVE, executables=executables)
    service.warmup([p])
    n_exe, misses = len(service.executables), service.metrics.cache_misses
    for i in range(4):
        service.set_accuracy(fit(0.3 + 0.1 * i, 0.8 - 0.1 * i))
        service.set_accuracy(fit(0.9 - 0.1 * i, 0.1 + 0.1 * i), tenant=f"t{i}")
        service.submit(p, tenant=f"t{i}", now=float(i))
        service.submit(p, now=float(i))
        done, _ = service.drain(now=float(i))
        assert len(done) == 2
    assert len(service.executables) == n_exe
    assert service.metrics.cache_misses == misses


# ---------------------------------------------------------------------------
# driver layer: tenant registry over the threaded real-clock path
# ---------------------------------------------------------------------------


@settings(max_examples=max(8, N_EXAMPLES // 5), deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    a0=st.floats(min_value=0.3, max_value=0.9),
    b0=st.floats(min_value=0.1, max_value=0.9),
    a1=st.floats(min_value=0.3, max_value=0.9),
    b1=st.floats(min_value=0.1, max_value=0.9),
)
def test_prop_driver_tenants_as_if_alone(executables, seed, a0, b0, a1, b1):
    """Through the threaded driver: two tenants with registered fits each get
    the solo-service answer for their own fit, whatever co-batching the
    micro-batcher happened to do."""
    p0, p1 = params_for(seed), params_for(seed + 1)
    f0, f1 = fit(a0, b0), fit(a1, b1)
    service = AllocService(SERVE, executables=executables)
    service.set_accuracy(f0, tenant="t0")
    service.set_accuracy(f1, tenant="t1")
    with RealClockDriver(service) as driver:
        fut0 = driver.submit(p0, tenant="t0")
        fut1 = driver.submit(p1, tenant="t1")
        c0 = fut0.result(timeout=WAIT_S)
        c1 = fut1.result(timeout=WAIT_S)
    for c, p, f in ((c0, p0, f0), (c1, p1, f1)):
        assert_alloc_equal(c.alloc, _solo_alloc(p, f, executables).alloc)
        assert bool(feasible(p, c.alloc))
