"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _qkv(key, B, S, H, KV, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA
    (1, 256, 8, 1, 32),      # MQA, small head
    (1, 192, 2, 2, 128),     # S not a block multiple (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, H, KV, hd, dtype):
    from repro.kernels.flash_attention import ops, ref

    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, hd, dtype)
    got = ops.flash_attention(q, k, v, causal=True, use_pallas=True,
                              interpret=True, bq=64, bk=64)
    want = ref.naive_attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("window,cap,causal", [
    (64, None, True),        # sliding window
    (None, 50.0, True),      # gemma softcap
    (None, None, False),     # encoder (bidirectional)
])
def test_flash_attention_variants(window, cap, causal):
    from repro.kernels.flash_attention import ops, ref

    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 256, 4, 2, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                              use_pallas=True, interpret=True, bq=64, bk=64)
    want = ref.naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_jnp_flash_matches_naive():
    """The model's chunked-jnp path is itself validated against the oracle."""
    from repro.kernels.flash_attention import ref
    from repro.models.attention import flash_attention as jnp_flash

    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 200, 4, 2, 64, jnp.float32)
    pos = jnp.arange(200, dtype=jnp.int32)
    got = jnp_flash(q, k, v, q_positions=pos, kv_positions=pos,
                    causal=True, window=64, q_chunk=64, kv_chunk=64)
    want = ref.naive_attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,hd", [(1, 2, 128, 64), (2, 4, 96, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(B, H, S, hd, dtype):
    from repro.kernels.rwkv6_scan import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, hd))).astype(jnp.float32) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd), dtype)
    got = ops.rwkv6_scan(r, k, v, w.astype(dtype), u, use_pallas=True,
                         interpret=True, ct=32)
    want = ref.rwkv6_scan_ref(r, k, v, w.astype(dtype), u)[0]
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_rwkv6_model_uses_equivalent_recurrence():
    """The model's time_mix scan equals the kernel oracle on matched inputs."""
    from repro.kernels.rwkv6_scan import ref

    B, H, S, hd = 1, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    w = jnp.full((B, H, S, hd), 0.9)
    u = jax.random.normal(ks[4], (H, hd))
    y, _ = ref.rwkv6_scan_ref(r, k, v, w, u)
    # manual recurrence
    S_state = np.zeros((B, H, hd, hd), np.float32)
    outs = np.zeros((B, H, S, hd), np.float32)
    rn, kn, vn, wn, un = map(np.asarray, (r, k, v, w, u))
    for t in range(S):
        kv = kn[:, :, t, :, None] * vn[:, :, t, None, :]
        outs[:, :, t] = np.einsum(
            "bhk,bhkv->bhv", rn[:, :, t], S_state + un[None, :, :, None] * kv
        )
        S_state = wn[:, :, t, :, None] * S_state + kv
    np.testing.assert_allclose(np.asarray(y), outs, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,N", [(1, 64, 128, 8), (2, 96, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(B, S, di, N, dtype):
    from repro.kernels.mamba_scan import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (B, S, di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))).astype(jnp.float32) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    A = -jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (di, N)))
    D = jnp.ones((di,), jnp.float32)
    got = ops.mamba_scan(x, dt.astype(dtype), Bm, Cm, A, D, use_pallas=True,
                         interpret=True, ct=32, bd=32)
    want = ref.mamba_scan_ref(x, dt.astype(dtype), Bm, Cm, A, D)[0]
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# fedsem objective grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,N", [(512, 4), (1024, 10), (700, 6)])
@pytest.mark.parametrize("masked", [False, True], ids=["full", "masked"])
def test_fedsem_objective_grid(G, N, masked):
    from repro.core import Weights, sample_params
    from repro.kernels.fedsem_objective import ops, ref

    params = sample_params(jax.random.PRNGKey(7), N=N, K=2 * N)
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    f = jax.random.uniform(ks[0], (G, N), minval=1e8, maxval=2e9)
    p = jax.random.uniform(ks[1], (G, N), minval=1e-3, maxval=0.1)
    r = jax.random.uniform(ks[2], (G, N), minval=1e5, maxval=3e7)
    rho = jax.random.uniform(ks[3], (G,), minval=0.05, maxval=1.0)
    dev_mask = (
        jnp.asarray([1.0] * (N - N // 2) + [0.0] * (N // 2)) if masked else None
    )
    args = (f, p, r, rho, params.c, params.d, params.D, params.C,
            params.t_sc_max, params.f_max, float(params.xi), float(params.eta),
            1.0, 1.0, 1.0)
    got = ops.objective_grid(*args, dev_mask=dev_mask, use_pallas=True, interpret=True)
    want = ref.objective_grid(*args, dev_mask=dev_mask)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4
    )


def test_fedsem_objective_grid_masked_matches_system_objective():
    """Regression: the grid evaluator was mask-unaware — it scored accuracy
    with the raw padded device count and ran feasibility checks over padded
    rows, so the exhaustive/random-search baselines (which route through
    `ops.objective_grid`) disagreed with the mask-aware `system.objective` on
    any `pad_params`-padded scenario."""
    from repro.core import Allocation, Weights, pad_params, sample_params
    from repro.core.allocator import equal_start, harden_x
    from repro.core.system import device_power, device_rate, objective
    from repro.kernels.fedsem_objective import ops

    p = sample_params(jax.random.PRNGKey(9), N=4, K=8)
    pp = pad_params(p, 8, 16)
    f, P, X = equal_start(pp)
    X = harden_x(X, pp.N, pp.K, pp.dev_mask, pp.sc_mask)
    rho = jnp.float32(0.7)
    # padded rows carry garbage the masks must neutralise: candidate f above
    # the padded f_max (= 1.0) used to trip the feasibility check to +inf
    f = jnp.where(pp.dev_mask > 0, f, 2.0)
    r = device_rate(pp, P, X)
    p_n = device_power(P)
    for use_pallas in (False, True):
        got = ops.objective_grid(
            f[None], p_n[None], r[None], rho[None],
            pp.c, pp.d, pp.D, pp.C, pp.t_sc_max, pp.f_max,
            float(pp.xi), float(pp.eta), 1.0, 1.0, 1.0,
            dev_mask=pp.dev_mask, use_pallas=use_pallas, interpret=use_pallas,
        )
        want = objective(pp, Weights.ones(), Allocation(f=f, P=P, X=X, rho=rho))
        assert np.isfinite(float(got[0])), "masked feasibility flagged padded row"
        np.testing.assert_allclose(float(got[0]), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# fedsem objective — batched-over-scenarios kernel (PR 4)
# ---------------------------------------------------------------------------

def _batch_grid_inputs(key, B, G, N, masked_rows=True):
    """Random (B, G, N) candidate grids + per-scenario parameter rows."""
    ks = jax.random.split(key, 9)
    f = jax.random.uniform(ks[0], (B, G, N), minval=1e8, maxval=2e9)
    p = jax.random.uniform(ks[1], (B, G, N), minval=1e-3, maxval=0.1)
    r = jax.random.uniform(ks[2], (B, G, N), minval=1e5, maxval=3e7)
    rho = jax.random.uniform(ks[3], (B, G), minval=0.05, maxval=1.0)
    c = jax.random.uniform(ks[4], (B, N), minval=1e3, maxval=1e4)
    d = jax.random.uniform(ks[5], (B, N), minval=1e5, maxval=1e6)
    D = jax.random.uniform(ks[6], (B, N), minval=1e5, maxval=1e6)
    C = jax.random.uniform(ks[7], (B, N), minval=1e5, maxval=1e6)
    tsc = jnp.full((B, N), 0.5)
    fmax = jnp.full((B, N), 2e9)
    mask = (
        (jax.random.uniform(ks[8], (B, N)) > 0.4).astype(jnp.float32)
        .at[:, 0].set(1.0)                       # >= 1 real device per scenario
        if masked_rows
        else jnp.ones((B, N), jnp.float32)
    )
    return (f, p, r, rho, c, d, D, C, tsc, fmax), mask


@pytest.mark.parametrize("B,G,N", [
    (3, 700, 4),     # padded candidate axis (700 -> 768), per-row masks
    (1, 6, 5),       # B=1 degenerate batch, tiny multi-start-sized G
    (8, 1, 6),       # G=1: one allocation per scenario (the serving path)
])
@pytest.mark.parametrize("feasible_mask", [True, False], ids=["feas", "raw"])
def test_fedsem_objective_batch_kernel_matches_ref(B, G, N, feasible_mask):
    """Batched Pallas grid (interpret) vs the batched jnp oracle, per-scenario
    dev_mask rows and per-scenario runtime weights. The infeasibility mask
    must agree exactly; finite scores to a couple of float32 ulps (the kernel
    is jit-compiled, the oracle eager — XLA's FMA/reduction codegen differs
    at that level between the two layouts)."""
    from repro.kernels.fedsem_objective import ops, ref

    args, mask = _batch_grid_inputs(jax.random.PRNGKey(11), B, G, N)
    kap = (jnp.linspace(0.5, 2.0, B), jnp.ones((B,)), jnp.full((B,), 1.3))
    kw = dict(xi=1e-28, eta=10, accuracy_ab=(0.6356, 0.4025), dev_mask=mask,
              check_feasible=feasible_mask)
    got = np.asarray(ops.objective_grid_batch(
        *args, *kap, use_pallas=True, interpret=True, **kw
    ))
    want = np.asarray(ref.objective_grid_batch(*args, *kap, **kw))
    assert got.shape == (B, G)
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want))
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=5e-7, atol=1e-5)


def test_fedsem_objective_batch_ref_equals_per_scenario_ref():
    """The batched oracle is exactly B stacked single-scenario oracles."""
    from repro.kernels.fedsem_objective import ref

    B, G, N = 4, 33, 5
    args, mask = _batch_grid_inputs(jax.random.PRNGKey(12), B, G, N)
    f, p, r, rho, c, d, D, C, tsc, fmax = args
    kap = np.linspace(0.7, 1.4, B)
    batch = ref.objective_grid_batch(
        *args, kap, 1.0, 1.0, xi=1e-28, eta=10, dev_mask=mask
    )
    for b in range(B):
        one = ref.objective_grid(
            f[b], p[b], r[b], rho[b], c[b], d[b], D[b], C[b], tsc[b], fmax[b],
            1e-28, 10, float(kap[b]), 1.0, 1.0, dev_mask=mask[b],
        )
        np.testing.assert_array_equal(np.asarray(batch[b]), np.asarray(one))


@pytest.mark.parametrize("use_pallas", [False, True], ids=["jnp", "pallas"])
def test_scoring_matches_system_objective_across_padded_buckets(use_pallas):
    """`core.scoring` (the allocator/serving scoring path) == mask-aware
    `system.objective`, scenario by scenario, on a padded-bucket batch with
    per-scenario weights — the kernel==ref==system three-way parity the
    batched objective path rests on."""
    from repro.core import (
        Weights, pad_params, sample_params, stack_params, stack_weights,
    )
    from repro.core.allocator import equal_start, harden_x
    from repro.core.scoring import batch_objectives
    from repro.core.system import objective
    from repro.core.types import Allocation

    scenarios, allocs, weights = [], [], []
    bbar = 20e6 / 8                      # shared so the padded B meta matches
    for i, (n, k) in enumerate([(3, 7), (4, 8), (2, 5), (4, 8)]):
        p = sample_params(jax.random.PRNGKey(20 + i), N=n, K=k, B=bbar * k)
        pp = pad_params(p, 4, 8)
        f, P, X = equal_start(pp)
        X = harden_x(X, pp.N, pp.K, pp.dev_mask, pp.sc_mask)
        # padded rows carry garbage the masks must neutralise
        f = jnp.where(pp.dev_mask > 0, f, 2.0)
        scenarios.append(pp)
        allocs.append(Allocation(f=f, P=P, X=X, rho=jnp.float32(0.4 + 0.1 * i)))
        weights.append(Weights(jnp.float32(0.5 + i), jnp.float32(1.0),
                               jnp.float32(1.5)))

    pb = stack_params(scenarios)
    ab = jax.tree.map(lambda *xs: jnp.stack(xs), *allocs)
    wb = stack_weights(weights)
    got = batch_objectives(
        pb, wb, ab, weights_batched=True,
        use_pallas=use_pallas, interpret=use_pallas,
    )
    for i, (pp, alloc, w) in enumerate(zip(scenarios, allocs, weights)):
        want = float(objective(pp, w, alloc))
        np.testing.assert_allclose(float(got[i]), want, rtol=1e-5)


@pytest.mark.parametrize("use_pallas", [False, True], ids=["jnp", "pallas"])
def test_candidate_scoring_matches_system_objective(use_pallas):
    """Per-scenario multi-start scoring (`candidate_objectives`) matches a
    python loop of `system.objective` calls — including under vmap, which is
    exactly how `solve_batch` reaches the batched kernel."""
    from repro.core import Weights, sample_params
    from repro.core.allocator import equal_start, low_power_start
    from repro.core.scoring import candidate_objectives
    from repro.core.system import objective
    from repro.core.types import Allocation

    p = sample_params(jax.random.PRNGKey(30), N=4, K=12)
    w = Weights.ones()
    cands = []
    for start, rho in [(equal_start(p), 0.9), (low_power_start(p), 0.5)]:
        f, P, X = start
        cands.append(Allocation(f=f, P=P, X=X, rho=jnp.float32(rho)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *cands)
    got = candidate_objectives(
        p, w, stacked, use_pallas=use_pallas, interpret=use_pallas
    )
    want = np.asarray([float(objective(p, w, a)) for a in cands])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_solve_batch_kernel_objective_matches_jnp_objective():
    """Regression for the default `use_kernel_objective` routing: scoring the
    multi-start selection through the batched kernel path picks the identical
    hardened X (and bitwise-identical alloc — selection is all it changes
    post-hardening) as the plain `system.objective` path."""
    from repro.core import AllocatorConfig, Weights, sample_params_batch, solve_batch

    pb = sample_params_batch(jax.random.PRNGKey(40), 4, N=4, K=12)
    w = Weights.ones()
    on = solve_batch(pb, w, AllocatorConfig(inner="pgd"))
    off = solve_batch(
        pb, w, AllocatorConfig(inner="pgd", use_kernel_objective=False)
    )
    np.testing.assert_array_equal(np.asarray(on.alloc.X), np.asarray(off.alloc.X))
    np.testing.assert_array_equal(np.asarray(on.alloc.P), np.asarray(off.alloc.P))
    np.testing.assert_array_equal(
        np.asarray(on.alloc.rho), np.asarray(off.alloc.rho)
    )
    # the trace IS scored differently (kernel vs jnp) — but only to fp noise
    np.testing.assert_allclose(
        np.asarray(on.trace), np.asarray(off.trace), rtol=1e-5
    )


def test_serve_completion_objective_scored_through_kernel():
    """Serving flushes score their padded-bucket batch through the batched
    kernel: `Completion.objective` == `system.objective` of the returned
    exact-shape allocation."""
    from repro.core import Weights, sample_params
    from repro.core.system import objective
    from repro.serve import AllocService, ServeConfig

    svc = AllocService(ServeConfig())
    reqs = [sample_params(jax.random.PRNGKey(50 + i), N=3 + i % 2, K=8)
            for i in range(4)]
    for i, p in enumerate(reqs):
        svc.submit(p, now=0.01 * i)
    done, _ = svc.drain(now=1.0)
    assert len(done) == len(reqs)
    for comp in done:
        p = reqs[comp.req_id]
        want = float(objective(p, Weights.ones(), comp.alloc))
        np.testing.assert_allclose(comp.objective, want, rtol=1e-5)
    # and the switch exists for latency-critical deployments
    svc_off = AllocService(ServeConfig(score_objective=False))
    svc_off.submit(reqs[0], now=0.0)
    done_off, _ = svc_off.drain(now=1.0)
    assert done_off[0].objective is None


def test_exhaustive_padded_scores_like_exact():
    """`solve_exhaustive` through the mask-aware grid on a padded scenario:
    before the fix every candidate tripped the f > f_max check on the padded
    row (padded f_max = 1.0) and scored accuracy with the padded device count,
    so the search returned +inf / wrong values. Masked, the padded best is
    finite and at least as good as the exact-shape best — the padded space is
    a superset (a real subcarrier owned by a padded device == legally
    unassigned, an option the exact owner-per-subcarrier enumeration lacks)."""
    from repro.core import Weights, pad_params, sample_params
    from repro.core.exhaustive import solve_exhaustive

    p = sample_params(jax.random.PRNGKey(10), N=2, K=3)
    pp = pad_params(p, 3, 4)
    grids = (np.array([5e8, 1e9]), np.array([10.0, 17.0]), np.array([0.5, 1.0]))
    exact = solve_exhaustive(p, Weights.ones(), *grids)
    padded = solve_exhaustive(pp, Weights.ones(), *grids)
    assert np.isfinite(float(padded.value))
    assert float(padded.value) <= float(exact.value) + 1e-6
