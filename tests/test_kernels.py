"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _qkv(key, B, S, H, KV, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA
    (1, 256, 8, 1, 32),      # MQA, small head
    (1, 192, 2, 2, 128),     # S not a block multiple (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, H, KV, hd, dtype):
    from repro.kernels.flash_attention import ops, ref

    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, hd, dtype)
    got = ops.flash_attention(q, k, v, causal=True, use_pallas=True,
                              interpret=True, bq=64, bk=64)
    want = ref.naive_attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("window,cap,causal", [
    (64, None, True),        # sliding window
    (None, 50.0, True),      # gemma softcap
    (None, None, False),     # encoder (bidirectional)
])
def test_flash_attention_variants(window, cap, causal):
    from repro.kernels.flash_attention import ops, ref

    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 256, 4, 2, 64, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                              use_pallas=True, interpret=True, bq=64, bk=64)
    want = ref.naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_jnp_flash_matches_naive():
    """The model's chunked-jnp path is itself validated against the oracle."""
    from repro.kernels.flash_attention import ref
    from repro.models.attention import flash_attention as jnp_flash

    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 200, 4, 2, 64, jnp.float32)
    pos = jnp.arange(200, dtype=jnp.int32)
    got = jnp_flash(q, k, v, q_positions=pos, kv_positions=pos,
                    causal=True, window=64, q_chunk=64, kv_chunk=64)
    want = ref.naive_attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,hd", [(1, 2, 128, 64), (2, 4, 96, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(B, H, S, hd, dtype):
    from repro.kernels.rwkv6_scan import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, hd))).astype(jnp.float32) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd), dtype)
    got = ops.rwkv6_scan(r, k, v, w.astype(dtype), u, use_pallas=True,
                         interpret=True, ct=32)
    want = ref.rwkv6_scan_ref(r, k, v, w.astype(dtype), u)[0]
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_rwkv6_model_uses_equivalent_recurrence():
    """The model's time_mix scan equals the kernel oracle on matched inputs."""
    from repro.kernels.rwkv6_scan import ref

    B, H, S, hd = 1, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    w = jnp.full((B, H, S, hd), 0.9)
    u = jax.random.normal(ks[4], (H, hd))
    y, _ = ref.rwkv6_scan_ref(r, k, v, w, u)
    # manual recurrence
    S_state = np.zeros((B, H, hd, hd), np.float32)
    outs = np.zeros((B, H, S, hd), np.float32)
    rn, kn, vn, wn, un = map(np.asarray, (r, k, v, w, u))
    for t in range(S):
        kv = kn[:, :, t, :, None] * vn[:, :, t, None, :]
        outs[:, :, t] = np.einsum(
            "bhk,bhkv->bhv", rn[:, :, t], S_state + un[None, :, :, None] * kv
        )
        S_state = wn[:, :, t, :, None] * S_state + kv
    np.testing.assert_allclose(np.asarray(y), outs, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,N", [(1, 64, 128, 8), (2, 96, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(B, S, di, N, dtype):
    from repro.kernels.mamba_scan import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (B, S, di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))).astype(jnp.float32) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    A = -jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (di, N)))
    D = jnp.ones((di,), jnp.float32)
    got = ops.mamba_scan(x, dt.astype(dtype), Bm, Cm, A, D, use_pallas=True,
                         interpret=True, ct=32, bd=32)
    want = ref.mamba_scan_ref(x, dt.astype(dtype), Bm, Cm, A, D)[0]
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# fedsem objective grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,N", [(512, 4), (1024, 10), (700, 6)])
@pytest.mark.parametrize("masked", [False, True], ids=["full", "masked"])
def test_fedsem_objective_grid(G, N, masked):
    from repro.core import Weights, sample_params
    from repro.kernels.fedsem_objective import ops, ref

    params = sample_params(jax.random.PRNGKey(7), N=N, K=2 * N)
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    f = jax.random.uniform(ks[0], (G, N), minval=1e8, maxval=2e9)
    p = jax.random.uniform(ks[1], (G, N), minval=1e-3, maxval=0.1)
    r = jax.random.uniform(ks[2], (G, N), minval=1e5, maxval=3e7)
    rho = jax.random.uniform(ks[3], (G,), minval=0.05, maxval=1.0)
    dev_mask = (
        jnp.asarray([1.0] * (N - N // 2) + [0.0] * (N // 2)) if masked else None
    )
    args = (f, p, r, rho, params.c, params.d, params.D, params.C,
            params.t_sc_max, params.f_max, float(params.xi), float(params.eta),
            1.0, 1.0, 1.0)
    got = ops.objective_grid(*args, dev_mask=dev_mask, use_pallas=True, interpret=True)
    want = ref.objective_grid(*args, dev_mask=dev_mask)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4
    )


def test_fedsem_objective_grid_masked_matches_system_objective():
    """Regression: the grid evaluator was mask-unaware — it scored accuracy
    with the raw padded device count and ran feasibility checks over padded
    rows, so the exhaustive/random-search baselines (which route through
    `ops.objective_grid`) disagreed with the mask-aware `system.objective` on
    any `pad_params`-padded scenario."""
    from repro.core import Allocation, Weights, pad_params, sample_params
    from repro.core.allocator import equal_start, harden_x
    from repro.core.system import device_power, device_rate, objective
    from repro.kernels.fedsem_objective import ops

    p = sample_params(jax.random.PRNGKey(9), N=4, K=8)
    pp = pad_params(p, 8, 16)
    f, P, X = equal_start(pp)
    X = harden_x(X, pp.N, pp.K, pp.dev_mask, pp.sc_mask)
    rho = jnp.float32(0.7)
    # padded rows carry garbage the masks must neutralise: candidate f above
    # the padded f_max (= 1.0) used to trip the feasibility check to +inf
    f = jnp.where(pp.dev_mask > 0, f, 2.0)
    r = device_rate(pp, P, X)
    p_n = device_power(P)
    for use_pallas in (False, True):
        got = ops.objective_grid(
            f[None], p_n[None], r[None], rho[None],
            pp.c, pp.d, pp.D, pp.C, pp.t_sc_max, pp.f_max,
            float(pp.xi), float(pp.eta), 1.0, 1.0, 1.0,
            dev_mask=pp.dev_mask, use_pallas=use_pallas, interpret=use_pallas,
        )
        want = objective(pp, Weights.ones(), Allocation(f=f, P=P, X=X, rho=rho))
        assert np.isfinite(float(got[0])), "masked feasibility flagged padded row"
        np.testing.assert_allclose(float(got[0]), float(want), rtol=1e-5)


def test_exhaustive_padded_scores_like_exact():
    """`solve_exhaustive` through the mask-aware grid on a padded scenario:
    before the fix every candidate tripped the f > f_max check on the padded
    row (padded f_max = 1.0) and scored accuracy with the padded device count,
    so the search returned +inf / wrong values. Masked, the padded best is
    finite and at least as good as the exact-shape best — the padded space is
    a superset (a real subcarrier owned by a padded device == legally
    unassigned, an option the exact owner-per-subcarrier enumeration lacks)."""
    from repro.core import Weights, pad_params, sample_params
    from repro.core.exhaustive import solve_exhaustive

    p = sample_params(jax.random.PRNGKey(10), N=2, K=3)
    pp = pad_params(p, 3, 4)
    grids = (np.array([5e8, 1e9]), np.array([10.0, 17.0]), np.array([0.5, 1.0]))
    exact = solve_exhaustive(p, Weights.ones(), *grids)
    padded = solve_exhaustive(pp, Weights.ones(), *grids)
    assert np.isfinite(float(padded.value))
    assert float(padded.value) <= float(exact.value) + 1e-6
