"""Numerical equivalence of the distributed paths vs their local references,
on a miniature host mesh (4 devices via conftest XLA_FLAGS)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.models.config import smoke_variant
from repro.models.layers import cross_entropy


def _mesh_or_skip(shape, names):
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        pytest.skip(f"need {n} host devices")
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), names)


def test_sharded_cross_entropy_matches_plain():
    mesh = _mesh_or_skip((2, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, S, V = 4, 8, 64
    logits = jax.random.normal(key, (B, S, V), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    labels = labels.at[0, 0].set(-1)  # ignored position

    want = float(cross_entropy(logits, labels))
    with mesh:
        got = float(M._sharded_cross_entropy(logits, labels, mesh))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_sharded_cross_entropy_grad_matches():
    mesh = _mesh_or_skip((2, 2), ("data", "model"))
    B, S, V = 4, 8, 32
    logits = jax.random.normal(jax.random.PRNGKey(2), (B, S, V), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, V)

    g_plain = jax.grad(lambda l: cross_entropy(l, labels))(logits)
    with mesh:
        g_shard = jax.grad(lambda l: M._sharded_cross_entropy(l, labels, mesh))(logits)
    np.testing.assert_allclose(np.asarray(g_shard), np.asarray(g_plain),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("layout", ["ep", "2d"])
def test_moe_distributed_matches_local(layout):
    mesh = _mesh_or_skip((2, 2), ("data", "model"))
    cfg = smoke_variant(get_config("deepseek_v3_671b")).scaled(
        n_experts=4, top_k=2, n_shared_experts=1, moe_2d=(layout == "2d"),
        capacity_factor=8.0,  # avoid drops so local == distributed exactly
    )
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

    out_local, aux_local = moe_mod.moe_ffn(p, cfg.scaled(moe_2d=False), x, mesh=None)
    with mesh:
        out_dist, aux_dist = jax.jit(
            lambda p, x: moe_mod.moe_ffn(p, cfg, x, mesh=mesh)
        )(p, x)
    np.testing.assert_allclose(
        np.asarray(out_dist), np.asarray(out_local), atol=2e-4, rtol=2e-4
    )
    # aux is a per-shard load-balance *estimator* (nonlinear statistic) —
    # only outputs are bit-matched; aux agrees loosely
    np.testing.assert_allclose(float(aux_dist), float(aux_local), rtol=0.15)


def test_moe_dispatch_respects_capacity():
    """Property: with capacity factor 1.0 some assignments drop, and dropped
    tokens simply lose that expert's contribution (output stays finite)."""
    cfg = smoke_variant(get_config("arctic_480b")).scaled(
        n_experts=4, top_k=2, capacity_factor=0.5, moe_dense_residual=True
    )
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model), jnp.float32)
    out, aux = moe_mod.moe_ffn_local(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_decode_matches_prefill_logits():
    """Step-by-step decode reproduces the teacher-forced forward logits."""
    cfg = smoke_variant(get_config("qwen2_5_3b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = M.forward(params, cfg, {"tokens": toks, "labels": toks})

    cache = M.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, toks[:, t : t + 1], jnp.int32(t), cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        atol=2e-2, rtol=2e-2,
    )


@pytest.mark.slow
def test_sliding_window_decode_ring_buffer():
    """Ring-buffer window cache == full cache when S <= window, and attends
    only the window when S > window."""
    cfg = smoke_variant(get_config("gemma2_2b"))  # local/global alternation
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B = 1
    S = cfg.sliding_window + 8  # exceed the window on local layers
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = M.forward(params, cfg, {"tokens": toks, "labels": toks})

    cache = M.init_cache(cfg, B, max_len=S)
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, toks[:, t : t + 1], jnp.int32(t), cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), np.asarray(full_logits[:, -1], np.float32),
        atol=5e-2, rtol=5e-2,
    )
