"""Direct coverage of `repro.semcom.autoencoder`: shape round-trips across
the extra-pool boundary, payload monotonicity, proxy-accuracy bounds, and the
runtime-rho (masked-bottleneck) codec's agreement with the shape-baked one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import image_batch
from repro.semcom.autoencoder import (
    AEConfig,
    compressed_bits_rho,
    decode,
    decode_rho,
    encode,
    encode_rho,
    forward,
    forward_rho,
    init_params,
    latent_mask,
    mse_loss_rho,
    param_bits,
    proxy_accuracy,
    proxy_accuracy_rho,
)

CFG = AEConfig(image_size=16, hidden=4, base_latent=4)


def _x(batch=2, size=16):
    return image_batch(jax.random.PRNGKey(1), batch, size=size)


# ---------------------------------------------------------------------------
# shape round-trips straddling the extra_pool boundary (rho <= 0.5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho", [0.2, 0.5, 0.55, 0.8, 1.0])
def test_encode_decode_roundtrip_shapes(rho):
    cfg = CFG._replace(rho=rho)
    p = init_params(jax.random.PRNGKey(0), cfg)
    x = _x()
    z = encode(p, cfg, x)
    s = cfg.image_size // (4 if cfg.extra_pool else 2)
    assert z.shape == (2, s, s, cfg.latent_channels)
    y = decode(p, cfg, z)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("rho", [0.2, 0.5, 0.55, 1.0])
def test_runtime_rho_roundtrip_shapes(rho):
    """The masked-bottleneck codec round-trips at rho = 1 parameter shapes on
    BOTH sides of the pooling boundary."""
    p = init_params(jax.random.PRNGKey(0), CFG)
    x = _x()
    extra = rho <= 0.5
    z = encode_rho(p, CFG, x, rho, extra_pool=extra)
    s = CFG.image_size // (4 if extra else 2)
    assert z.shape == (2, s, s, CFG.base_latent)   # full channels, masked
    # masked channels are exactly zero
    keep = int(np.ceil(rho * CFG.base_latent))
    assert bool(jnp.all(z[..., keep:] == 0.0))
    y = decode_rho(p, CFG, z, extra_pool=extra)
    assert y.shape == x.shape
    y2 = forward_rho(p, CFG, x, rho, key=jax.random.PRNGKey(2))
    assert y2.shape == x.shape


def test_latent_mask_counts_and_floor():
    assert float(latent_mask(CFG, 1.0).sum()) == CFG.base_latent
    assert float(latent_mask(CFG, 0.5).sum()) == np.ceil(0.5 * CFG.base_latent)
    # at least one channel survives arbitrarily small rho
    assert float(latent_mask(CFG, 1e-6).sum()) == 1.0


def test_forward_rho_matches_forward_at_full_rate():
    """rho = 1: the mask is all ones and no extra pool — the runtime-rho
    codec IS the shape-baked one."""
    p = init_params(jax.random.PRNGKey(0), CFG)
    x = _x()
    np.testing.assert_allclose(
        np.asarray(forward(p, CFG._replace(rho=1.0), x)),
        np.asarray(forward_rho(p, CFG, x, 1.0)),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# payload size: monotone in rho, runtime == shape-baked accounting
# ---------------------------------------------------------------------------

def test_compressed_bits_monotone_in_rho():
    grid = np.linspace(0.05, 1.0, 24)
    bits = [AEConfig(rho=float(r)).compressed_bits for r in grid]
    assert all(b1 <= b2 for b1, b2 in zip(bits, bits[1:]))
    # the rho <= 0.5 pooling stage makes the jump at the boundary strict
    assert AEConfig(rho=0.5).compressed_bits < AEConfig(rho=0.51).compressed_bits


@pytest.mark.parametrize("rho", [0.1, 0.25, 0.5, 0.51, 0.75, 1.0])
def test_compressed_bits_rho_matches_config(rho):
    assert compressed_bits_rho(CFG, rho) == CFG._replace(rho=rho).compressed_bits


# ---------------------------------------------------------------------------
# proxy accuracy: bounded, degrades with channel noise
# ---------------------------------------------------------------------------

def test_proxy_accuracy_bounded_and_noise_degrades():
    p = init_params(jax.random.PRNGKey(0), CFG)
    x = _x(4)
    k = jax.random.PRNGKey(3)
    accs = {}
    for std in (0.0, 0.1, 3.0):
        cfg = CFG._replace(noise_std=std)
        a = float(proxy_accuracy(p, cfg, x, k))
        assert 0.0 <= a <= 1.0
        accs[std] = a
    assert accs[3.0] <= accs[0.1] <= accs[0.0]
    assert accs[3.0] < accs[0.0]      # a much louder channel must hurt

    # same property through the runtime-rho path
    a_clean = float(proxy_accuracy_rho(p, CFG._replace(noise_std=0.0), x, 0.75, k))
    a_noisy = float(proxy_accuracy_rho(p, CFG._replace(noise_std=3.0), x, 0.75, k))
    assert 0.0 <= a_noisy <= a_clean <= 1.0


def test_mse_loss_rho_grad_through_cond():
    """The per-round loss used by `SemComJob`: traced rho selecting the
    pooling branch via lax.cond must stay differentiable."""
    p = init_params(jax.random.PRNGKey(0), CFG)
    x = _x()

    def loss(p, rho):
        return jax.lax.cond(
            rho <= 0.5,
            lambda: mse_loss_rho(p, CFG, x, rho, extra_pool=True),
            lambda: mse_loss_rho(p, CFG, x, rho, extra_pool=False),
        )

    for rho in (0.3, 0.8):
        g = jax.grad(loss)(p, jnp.float32(rho))
        flat = jnp.concatenate([v.ravel() for v in jax.tree_util.tree_leaves(g)])
        assert bool(jnp.all(jnp.isfinite(flat)))
        assert float(jnp.abs(flat).max()) > 0.0


def test_param_bits_is_shared_tree_bits():
    from repro.core import tree_bits

    p = init_params(jax.random.PRNGKey(0), CFG)
    assert param_bits(p) == tree_bits(p)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(p))
    assert param_bits(p) == 32.0 * n_params
