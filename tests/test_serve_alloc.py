"""Serving subsystem: padded-bucket solves == exact-shape solves, every
admitted request gets a feasible hardened allocation, micro-batching policy,
compiled-executable cache, and the batched-weights validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AllocatorConfig,
    ShapeBucket,
    Weights,
    bucket_for,
    pad_params,
    sample_params,
    sample_request_stream,
    solve,
    solve_batch,
    stack_params,
    stack_weights,
    tree_index,
    unpad_alloc,
)
from repro.core.allocator import harden_x
from repro.core.p5 import P5Config
from repro.core.pgd import PGDConfig
from repro.core.system import feasible, objective
from repro.serve import AllocService, BatchPolicy, ServeConfig, poisson_arrivals, run_load

W = Weights.ones()
# reduced iteration counts keep compiles/solves test-sized; equivalence holds
# per-config (padded and exact sides always share the config)
PGD_CFG = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=80))
SCA_CFG = AllocatorConfig(inner="sca", outer_iters=2, p5=P5Config(outer_iters=2, inner_iters=40))
SERVE_CFG = ServeConfig(
    policy=BatchPolicy(max_batch=2, max_wait_s=0.01),
    allocator=AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=40)),
)


# ---------------------------------------------------------------------------
# padding / mask helpers
# ---------------------------------------------------------------------------


def test_pad_params_shapes_masks_meta():
    p = sample_params(jax.random.PRNGKey(0), N=3, K=8)
    pp = pad_params(p, 4, 12)
    assert pp.g.shape == (4, 12) and pp.N == 4 and pp.K == 12
    np.testing.assert_array_equal(np.asarray(pp.dev_mask), [1, 1, 1, 0])
    assert np.asarray(pp.sc_mask).sum() == 8 and np.asarray(pp.sc_mask)[8:].sum() == 0
    # real block preserved, padding inert
    np.testing.assert_array_equal(np.asarray(pp.g[:3, :8]), np.asarray(p.g))
    assert float(jnp.abs(pp.g[3:]).max()) == 0.0
    assert float(jnp.abs(pp.C[3:]).max()) == 0.0 and float(jnp.abs(pp.d[3:]).max()) == 0.0
    # per-subcarrier bandwidth is what the rate math sees — preserved exactly
    assert pp.bbar == pytest.approx(p.bbar, rel=1e-12)


def test_pad_params_identity_and_reject_shrink():
    p = sample_params(jax.random.PRNGKey(0), N=4, K=12)
    assert pad_params(p, 4, 12) is p
    with pytest.raises(ValueError, match="shrink"):
        pad_params(p, 3, 12)


def test_bucket_for_picks_smallest_fit():
    assert bucket_for(3, 8) == ShapeBucket(4, 8)
    assert bucket_for(4, 12) == ShapeBucket(4, 16)
    assert bucket_for(10, 50) == ShapeBucket(16, 64)
    with pytest.raises(ValueError, match="bucket"):
        bucket_for(1000, 4000)


def test_default_masks_are_ones():
    p = sample_params(jax.random.PRNGKey(1), N=4, K=12)
    assert float(jnp.min(p.dev_mask)) == 1.0 and p.dev_mask.shape == (4,)
    assert float(jnp.min(p.sc_mask)) == 1.0 and p.sc_mask.shape == (12,)


def test_harden_x_masked_ignores_padding():
    key = jax.random.PRNGKey(2)
    X = jax.random.uniform(key, (5, 9))
    dev_mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    sc_mask = jnp.asarray([1.0] * 6 + [0.0] * 3)
    Xb = np.asarray(harden_x(X * dev_mask[:, None] * sc_mask[None, :], 5, 9, dev_mask, sc_mask))
    # padded rows/columns stay empty; every real device owns >= 1 real sc
    assert Xb[3:].sum() == 0 and Xb[:, 6:].sum() == 0
    assert (Xb[:3, :6].sum(axis=1) >= 1).all()
    assert (Xb.sum(axis=0) <= 1).all()
    # real block identical to hardening the exact-shape problem
    np.testing.assert_array_equal(Xb[:3, :6], np.asarray(harden_x(X[:3, :6], 3, 6)))


# ---------------------------------------------------------------------------
# padded solve == exact solve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [PGD_CFG, SCA_CFG], ids=["pgd", "sca"])
def test_padded_solve_matches_exact(cfg):
    p = sample_params(jax.random.PRNGKey(0), N=4, K=12)
    pp = pad_params(p, 8, 16)
    ref = jax.jit(lambda q: solve(q, W, cfg))(p)
    pad = jax.jit(lambda q: solve(q, W, cfg))(pp)
    # padded slots get nothing
    assert float(jnp.abs(pad.alloc.P[4:]).max()) == 0.0
    assert float(jnp.abs(pad.alloc.X[:, 12:]).max()) == 0.0
    a = unpad_alloc(pad.alloc, 4, 12)
    # discrete assignment must agree exactly; continuous vars to fp-chaos tol
    np.testing.assert_array_equal(np.asarray(a.X), np.asarray(ref.alloc.X))
    np.testing.assert_allclose(np.asarray(a.rho), np.asarray(ref.alloc.rho), rtol=5e-3)
    np.testing.assert_allclose(np.asarray(a.f), np.asarray(ref.alloc.f), rtol=5e-2)
    np.testing.assert_allclose(
        float(objective(p, W, a)), float(objective(p, W, ref.alloc)), rtol=1e-2
    )
    # the padded scenario's own objective sees the same value (masked accuracy
    # term, inert padding) — the bucket does not distort the decision problem
    np.testing.assert_allclose(
        float(objective(pp, W, pad.alloc)), float(objective(p, W, a)), rtol=1e-5
    )
    assert bool(feasible(p, a))


def test_padded_mixed_batch_all_feasible():
    scenarios = sample_request_stream(
        jax.random.PRNGKey(3), 4, sizes=((3, 8), (4, 8))
    )
    padded = [pad_params(s, 4, 8) for s in scenarios]
    res = solve_batch(stack_params(padded), W, PGD_CFG)
    for i, s in enumerate(scenarios):
        a = unpad_alloc(tree_index(res.alloc, i), s.N, s.K)
        assert bool(feasible(s, a)), f"scenario {i} infeasible"


# ---------------------------------------------------------------------------
# service: admission, micro-batching, cache, metrics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def load_run():
    requests = sample_request_stream(
        jax.random.PRNGKey(7), 6, sizes=((3, 8), (4, 8))
    )
    service = AllocService(SERVE_CFG)
    arrivals = poisson_arrivals(jax.random.PRNGKey(8), len(requests), rate_hz=200.0)
    result = run_load(service, requests, arrivals)
    return requests, service, result


def test_service_answers_every_request_feasibly(load_run):
    requests, _, result = load_run
    assert len(result.completions) == len(requests)
    assert sorted(c.req_id for c in result.completions) == list(range(len(requests)))
    for c in result.completions:
        p = requests[c.req_id]
        assert c.alloc.P.shape == (p.N, p.K)         # exact shape back
        assert bool(feasible(p, c.alloc)), f"request {c.req_id} infeasible"
        # hardened: binary X, every device serviced
        X = np.asarray(c.alloc.X)
        assert set(np.unique(X)).issubset({0.0, 1.0})
        assert (X.sum(axis=1) >= 1).all()


def test_service_metrics(load_run):
    _, service, result = load_run
    s = result.summary
    assert s["completed"] == s["requests"] == 6
    assert s["latency_p95_s"] >= s["latency_p50_s"] > 0
    assert 0 < s["batch_occupancy_mean"] <= 1
    assert s["queue_depth_max"] >= 1
    assert result.throughput_rps > 0
    # both sizes share the (4, 8) bucket -> exactly one compiled executable
    assert s["cache_misses"] == 1
    assert s["cache_hits"] == s["batches"] - 1
    assert len(service.executables) == 1


def test_flush_on_max_batch():
    service = AllocService(SERVE_CFG)
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    service.submit(p, now=0.0)
    assert service.pending() == 1
    done, _ = service.flush_full(now=0.0)
    assert done == [] and service.pending() == 1     # not full yet
    service.submit(p, now=0.001)
    done, _ = service.flush_full(now=0.001)          # max_batch=2 reached
    assert len(done) == 2 and service.pending() == 0
    assert done[0].wait_s == pytest.approx(0.001)


def test_flush_on_max_wait():
    service = AllocService(SERVE_CFG)
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    service.submit(p, now=0.0)
    assert service.next_deadline() == pytest.approx(0.01)
    done, _ = service.flush_due(now=0.005)
    assert done == []                                # not due yet
    done, _ = service.flush_due(now=0.01)            # max_wait_s hit
    assert len(done) == 1
    assert done[0].latency_s >= 0.01                 # waited + solve time


def test_per_request_weights_respected():
    # a request served in the same batch with different weights must see its
    # own objective trade-off: huge kappa3 pushes rho to ~1
    p = sample_params(jax.random.PRNGKey(11), N=4, K=8)
    service = AllocService(SERVE_CFG)
    service.submit(p, Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(0.0)), now=0.0)
    service.submit(p, Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(500.0)), now=0.0)
    (c_lo, c_hi), _ = service.flush_full(now=0.0)
    assert float(c_hi.alloc.rho) >= float(c_lo.alloc.rho)
    assert float(c_hi.alloc.rho) > 0.99


def test_shared_cache_keyed_by_allocator_config():
    """A shared executables dict must never serve config A's solver to a
    service running config B (the cache key includes AllocatorConfig)."""
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    a = AllocService(SERVE_CFG)
    a.warmup([p])
    assert a.metrics.cache_misses == 1
    other = SERVE_CFG._replace(
        allocator=AllocatorConfig(inner="pgd", outer_iters=1, pgd=PGDConfig(steps=20))
    )
    b = AllocService(other, executables=a.executables)
    b.warmup([p])
    assert b.metrics.cache_misses == 1      # same bucket/slots, different cfg
    assert len(a.executables) == 2          # both entries live in the shared dict


def test_same_bbar_different_k_share_bucket():
    """Requests built from one bbar with different K must co-batch: the
    service canonicalises the padded B, so fp round-trip drift (bbar*12/12*16
    vs bbar*16) cannot split the bucket queue (regression)."""
    bbar = 8357815.274094777            # reproduces a 1-ulp B split unrounded
    p12 = sample_params(jax.random.PRNGKey(0), N=4, K=12, B=bbar * 12)
    p16 = sample_params(jax.random.PRNGKey(1), N=4, K=16, B=bbar * 16)
    service = AllocService(SERVE_CFG)
    k1 = service._bucket_key(service._pad(p12))
    k2 = service._bucket_key(service._pad(p16))
    assert k1 == k2
    service.submit(p12, now=0.0)
    service.submit(p16, now=0.0)
    done, _ = service.flush_full(now=0.0)   # max_batch=2: only fires co-bucketed
    assert len(done) == 2
    for c, p in zip(done, (p12, p16)):
        assert bool(feasible(p, c.alloc))


def test_exact_mode_canonicalises_b_ulp_split():
    """Regression: exact-shape mode (``buckets=None``) skipped the B
    canonicalisation, so two equal-bbar requests whose B was reconstructed
    through different float round-trips (1 ulp apart) landed in different
    queues — neither bucket ever filled, and had they shared a key,
    `stack_params` would have rejected mixing them. Both modes now
    canonicalise at `_pad`."""
    bbar = 84457742.9673523       # bbar * 12 != sum([bbar] * 12): 1 ulp apart
    b_mul, b_sum = bbar * 12, sum([bbar] * 12)
    assert b_mul != b_sum
    pa = sample_params(jax.random.PRNGKey(0), N=4, K=12, B=b_mul)
    pb = sample_params(jax.random.PRNGKey(1), N=4, K=12, B=b_sum)
    service = AllocService(SERVE_CFG._replace(buckets=None))
    assert service._bucket_key(service._pad(pa)) == service._bucket_key(service._pad(pb))
    service.submit(pa, now=0.0)
    service.submit(pb, now=0.0)
    done, _ = service.flush_full(now=0.0)    # max_batch=2: only fires co-queued
    assert len(done) == 2
    for c, p in zip(done, (pa, pb)):
        assert c.alloc.P.shape == (4, 12)
        assert bool(feasible(p, c.alloc))


# ---------------------------------------------------------------------------
# serving-loop correctness regressions (PR 5 satellites)
# ---------------------------------------------------------------------------


def test_arrival_tied_with_deadline_joins_the_flush():
    """Regression: the loadgen's deadline branch used to flush BEFORE
    admitting an arrival with t_arr == deadline, violating the documented
    invariant (everything with t_arr <= clock is queued before any flush
    decision at clock). The tied arrival must ride the due flush's batch."""
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    service = AllocService(SERVE_CFG)    # max_batch=2, max_wait_s=0.01
    service.warmup([p])
    # second arrival lands EXACTLY on the first request's bucket deadline
    result = run_load(service, [p, p], arrivals=[0.0, 0.01])
    assert len(result.completions) == 2
    # one batch of two: the tied arrival was admitted first, filling the
    # bucket (pre-fix: two solo flushes, batches == 2, occupancy 0.5)
    assert result.summary["batches"] == 1
    assert result.summary["mean_batch_size"] == 2.0
    waits = {c.req_id: c.wait_s for c in result.completions}
    assert waits[0] == pytest.approx(0.01)   # waited out max_wait_s
    assert waits[1] == pytest.approx(0.0)    # flushed on arrival


def test_run_load_validates_weights_length():
    """Regression: a short weights list used to IndexError mid-run; it must
    fail at admission."""
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    service = AllocService(SERVE_CFG)
    with pytest.raises(ValueError, match="weights \\(1\\)"):
        run_load(service, [p, p], arrivals=[0.0, 0.0], weights=[Weights.ones()])


def test_warmup_has_no_dead_now_param():
    """Regression: warmup() accepted (and ignored) a ``now`` timestamp."""
    import inspect

    assert "now" not in inspect.signature(AllocService.warmup).parameters


def test_metrics_reservoirs_are_bounded():
    """Regression: ServiceMetrics grew unbounded python lists — a leak under
    the indefinitely-running real-clock driver. Reservoirs cap retained
    samples while count/mean/max stay exact."""
    from repro.serve import Reservoir, ServiceMetrics

    r = Reservoir(cap=64, seed=0)
    for i in range(1000):
        r.add(float(i))
    assert len(r.sample) == 64              # bounded retention
    assert r.count == len(r) == 1000        # exact count
    assert r.mean() == pytest.approx(499.5)  # exact running mean
    assert r.max() == 999.0                 # exact running max
    assert 0.0 <= r.percentile(50.0) <= 999.0

    # below the cap the reservoir is exact, including percentiles
    small = Reservoir(cap=64)
    for i in range(10):
        small.add(float(i))
    assert small.sample == [float(i) for i in range(10)]
    assert small.percentile(100.0) == 9.0

    m = ServiceMetrics()
    for i in range(10_000):
        m.observe_submit(depth=i)
        m.observe_completion(latency_s=1.0, wait_s=0.5)
    for reservoir in (m.queue_depth, m.latencies_s, m.waits_s):
        assert len(reservoir.sample) <= reservoir.cap
    s = m.summary()                          # schema unchanged, values sane
    assert s["requests"] == s["completed"] == 10_000
    assert s["queue_depth_max"] == 9_999 and isinstance(s["queue_depth_max"], int)
    assert s["latency_p50_s"] == 1.0 and s["wait_p50_s"] == 0.5


def test_service_prepare_admit_round_trip():
    """The driver-facing split of submit(): prepare is pure (no queue state),
    admit stamps id/arrival and enqueues — together == submit."""
    p = sample_params(jax.random.PRNGKey(0), N=3, K=8)
    service = AllocService(SERVE_CFG)
    prepared = service.prepare(p)
    assert service.pending() == 0            # prepare touched no queue
    assert prepared.padded.N == 4 and prepared.padded.K == 8
    rid = service.admit(prepared, now=1.5)
    assert rid == 0 and service.pending() == 1
    assert prepared.arrival_t == 1.5
    assert service.next_deadline() == pytest.approx(1.5 + SERVE_CFG.policy.max_wait_s)


def test_set_buckets_mid_stream_keeps_queued_requests():
    """A ladder refit between admissions must not strand queued requests:
    they flush in the bucket they were admitted into."""
    from repro.serve import learn_buckets

    p = sample_params(jax.random.PRNGKey(0), N=3, K=8)
    service = AllocService(SERVE_CFG)
    service.submit(p, now=0.0)               # padded into DEFAULT (4, 8)
    service.set_buckets(learn_buckets({(3, 8): 1}))
    service.submit(p, now=0.0)               # padded into learned (3, 8)
    done, _ = service.drain(now=0.0)
    assert sorted(c.bucket for c in done) == [(3, 8), (4, 8)]
    for c in done:
        assert c.alloc.P.shape == (3, 8)
        assert bool(feasible(p, c.alloc))


# ---------------------------------------------------------------------------
# solve_batch weights validation (satellite)
# ---------------------------------------------------------------------------


def test_weights_batched_rejects_scalar_weights():
    pb = stack_params([sample_params(jax.random.PRNGKey(0), N=4, K=8)] * 3)
    with pytest.raises(ValueError, match="leading batch axis"):
        solve_batch(pb, Weights.ones(), PGD_CFG, weights_batched=True)


def test_weights_batched_rejects_wrong_batch():
    pb = stack_params([sample_params(jax.random.PRNGKey(0), N=4, K=8)] * 3)
    wb = stack_weights([Weights.ones()] * 2)
    with pytest.raises(ValueError, match="size B=3"):
        solve_batch(pb, wb, PGD_CFG, weights_batched=True)


def test_weights_batched_matches_per_scenario():
    p = sample_params(jax.random.PRNGKey(1), N=4, K=8)
    ws = [
        Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(1.0)),
        Weights(jnp.float32(4.0), jnp.float32(1.0), jnp.float32(1.0)),
    ]
    pb = stack_params([p, p])
    wb = stack_weights(ws)
    res = solve_batch(pb, wb, PGD_CFG, weights_batched=True)
    solve_jit = jax.jit(lambda w: solve(p, w, PGD_CFG))
    for i, w in enumerate(ws):
        ref = solve_jit(w)
        np.testing.assert_array_equal(
            np.asarray(tree_index(res.alloc.X, i)), np.asarray(ref.alloc.X)
        )
        np.testing.assert_allclose(
            np.asarray(tree_index(res.alloc.rho, i)), np.asarray(ref.alloc.rho),
            rtol=1e-4,
        )
