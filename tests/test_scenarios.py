"""Scenario registry correctness gates (every registered family).

The registry's contract is that diversity never outruns correctness: for
EVERY registered family — not just the relocated `iid_rayleigh` — the same
guarantees the repo asserts on the Section-V sampler must hold:

* `sample_batch` == stacked `sample` singles, leaf for leaf;
* draws stay finite/positive and survive `ShapeBucket` padding with the
  masks and ``bbar`` invariants intact;
* the allocator beats every paper baseline on the family's draws
  (hypothesis-property over seeds);
* on small (N, K) the exhaustive oracle cannot be much better than Alg. A2
  (the Table-II gate, per family);
* `solve_batch` through exact-shape and padded-bucket paths returns the
  identical hardened assignment (the serving stack's transparency contract,
  asserted here for the new `ris_geometry` / `hetero_classes` batches).

Plus the stateful stream law of `gauss_markov`: time-correlated,
replay-deterministic, and servable through the virtual-clock loadgen.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AllocatorConfig,
    Weights,
    sample_params,
    solve,
    solve_batch,
    stack_params,
    tree_index,
)
from repro.core import baselines as B
from repro.core.exhaustive import solve_exhaustive
from repro.core.pgd import PGDConfig
from repro.core.system import feasible, report
from repro.core.types import bucket_for, pad_params, unpad_alloc
from repro.scenarios import (
    DEFAULT_STREAM_BBAR,
    ScenarioFamily,
    build_classes,
    get_family,
    list_families,
    register,
)

FAMILIES = list_families()
W = Weights.ones()
#: reduced-iteration config for the many-small-solves tests (same pattern as
#: test_serve_alloc); the oracle/baseline gates use the full default PGD
PGD_CFG = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=80))
FULL_PGD = AllocatorConfig(inner="pgd")

#: one compiled solver shared across families (same (N, K) => same program)
_solve_full = jax.jit(lambda p: solve(p, W, FULL_PGD))
_solve_small = jax.jit(lambda p: solve(p, W, PGD_CFG))


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------


def test_four_families_registered():
    assert set(FAMILIES) >= {
        "iid_rayleigh", "ris_geometry", "gauss_markov", "hetero_classes",
    }


def test_get_family_unknown_name():
    with pytest.raises(ValueError, match="unknown scenario family"):
        get_family("nope")


def test_register_rejects_duplicates_and_unnamed():
    class Dup(ScenarioFamily):
        name = "iid_rayleigh"

    with pytest.raises(ValueError, match="already registered"):
        register(Dup())
    with pytest.raises(ValueError, match="no name"):
        register(ScenarioFamily())


def test_channel_shims_are_the_registry_family():
    """`repro.core.sample_params` (deprecated shim) == the registered
    iid_rayleigh family, bit for bit — existing call sites and regressions
    (e.g. the FL plan==sequential test) see unchanged draws."""
    key = jax.random.PRNGKey(3)
    a = sample_params(key, N=4, K=12)
    b = get_family("iid_rayleigh").sample(key, N=4, K=12)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# per-family invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_batch_equals_stacked_singles(name):
    fam = get_family(name)
    key = jax.random.PRNGKey(11)
    pb = fam.sample_batch(key, 3, N=4, K=12)
    singles = [fam.sample(k, N=4, K=12) for k in jax.random.split(key, 3)]
    ref = stack_params(singles)
    got_leaves, got_def = jax.tree.flatten(pb)
    ref_leaves, ref_def = jax.tree.flatten(ref)
    assert got_def == ref_def
    for a, b in zip(got_leaves, ref_leaves):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("name", FAMILIES)
def test_sample_finite_positive_and_padding_invariants(name):
    fam = get_family(name)
    p = fam.sample(jax.random.PRNGKey(5), N=3, K=8, B=DEFAULT_STREAM_BBAR * 8)
    for arr in (p.g, p.c, p.d, p.D, p.C, p.p_max, p.f_max, p.t_sc_max):
        a = np.asarray(arr)
        assert np.isfinite(a).all() and (a > 0).all(), name
    assert np.asarray(p.dev_mask).sum() == 3 and np.asarray(p.sc_mask).sum() == 8

    bucket = bucket_for(p.N, p.K)
    pp = pad_params(p, bucket.N, bucket.K)
    # bbar is the only way bandwidth enters the rate math; padding preserves it
    assert pp.B / pp.K == pytest.approx(p.B / p.K, rel=1e-6)
    assert np.asarray(pp.dev_mask).sum() == 3 and np.asarray(pp.sc_mask).sum() == 8
    assert np.isfinite(np.asarray(pp.g)).all()
    # padded-region gains contribute nothing real: mask rows/cols are zeroed
    g = np.asarray(pp.g)
    assert (g[3:, :] == 0).all() and (g[:, 8:] == 0).all()


@pytest.mark.parametrize("name", FAMILIES)
def test_allocation_feasible_on_family(name):
    p = get_family(name).sample(jax.random.PRNGKey(1), N=4, K=12)
    res = _solve_small(p)
    assert bool(feasible(p, res.alloc)), name
    assert np.isfinite(float(report(p, W, res.alloc)["objective"]))


@pytest.mark.parametrize("name", FAMILIES)
@hypothesis.settings(max_examples=3, deadline=None)
@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000))
def test_allocator_beats_all_baselines_on_family(name, seed):
    """The Fig.-4 gate, per registered family: Alg. A2 (full PGD inner) <=
    every paper baseline on this family's draws."""
    p = get_family(name).sample(jax.random.PRNGKey(seed), N=4, K=12)
    obj = float(report(p, W, _solve_full(p).alloc)["objective"])
    key = jax.random.PRNGKey(seed + 1)
    for base_name, alloc in [
        ("equal", B.equal_allocation(p)),
        ("comm_only", B.comm_opt_only(p, W, key)),
        ("comp_only", B.comp_opt_only(p, W)),
        ("random", B.random_allocation(p, key)),
    ]:
        base = float(report(p, W, alloc)["objective"])
        assert obj <= base + 1e-3, (
            f"{name}: proposed {obj} worse than {base_name} {base}"
        )


@pytest.mark.parametrize("name", FAMILIES)
def test_exhaustive_oracle_gate(name):
    """Table-II gate per family: on small (N, K) the exhaustive grid oracle
    must not be much better than Alg. A2 on this family's draws.

    Grids respect the tightest per-device budget (min f_max / min p_max), so
    the oracle never uses power or frequency some device doesn't have; the
    continuous allocator may exceed the coarse grid, hence the one-sided
    tolerance (same as benchmarks/table2)."""
    p = get_family(name).sample(jax.random.PRNGKey(2), N=3, K=4)
    obj = float(report(p, W, _solve_full(p).alloc)["objective"])

    f_hi = float(np.min(np.asarray(p.f_max)))
    p_hi_dbm = 10.0 * np.log10(float(np.min(np.asarray(p.p_max)))) + 30.0
    ex = solve_exhaustive(
        p, W,
        f_levels=np.linspace(0.25e9, f_hi, 4),
        p_levels_dbm=np.linspace(4.0, p_hi_dbm, 3),
        rho_levels=np.linspace(0.2, 1.0, 4),
    )
    assert np.isfinite(float(ex.value)), name
    assert float(ex.value) >= obj - 0.35 * abs(obj), (
        f"{name}: oracle {float(ex.value)} much better than proposed {obj}"
    )


@pytest.mark.parametrize("name", ("ris_geometry", "hetero_classes"))
def test_solve_batch_padded_equals_exact(name):
    """Acceptance gate: `solve_batch` over a family batch produces the
    identical hardened X through the exact-shape and padded-bucket paths."""
    fam = get_family(name)
    bbar = DEFAULT_STREAM_BBAR
    singles = [
        fam.sample(k, N=4, K=12, B=bbar * 12)
        for k in jax.random.split(jax.random.PRNGKey(9), 3)
    ]
    exact = solve_batch(stack_params(singles), W, PGD_CFG)

    bucket = bucket_for(4, 12)          # pads into (4, 16) under the defaults
    assert (bucket.N, bucket.K) != (4, 12)
    padded = solve_batch(
        stack_params([pad_params(s, bucket.N, bucket.K) for s in singles]),
        W, PGD_CFG,
    )
    for i, s in enumerate(singles):
        a_exact = tree_index(exact.alloc, i)
        a_pad = unpad_alloc(tree_index(padded.alloc, i), s.N, s.K)
        np.testing.assert_array_equal(
            np.asarray(a_pad.X), np.asarray(a_exact.X)
        )
        np.testing.assert_allclose(
            float(a_pad.rho), float(a_exact.rho), rtol=5e-3
        )
        assert bool(feasible(s, a_pad))


# ---------------------------------------------------------------------------
# streams
# ---------------------------------------------------------------------------


def test_stream_shares_bbar_across_sizes():
    reqs = get_family("ris_geometry").stream(
        jax.random.PRNGKey(4), 6, sizes=((3, 8), (4, 12))
    )
    assert {(r.N, r.K) for r in reqs} <= {(3, 8), (4, 12)}
    for r in reqs:
        assert r.B / r.K == pytest.approx(DEFAULT_STREAM_BBAR, rel=1e-6)


def test_stream_validates_sizes():
    fam = get_family("iid_rayleigh")
    with pytest.raises(ValueError, match="K >= N"):
        fam.stream(jax.random.PRNGKey(0), 4, sizes=((8, 4),))
    with pytest.raises(ValueError, match="n_requests"):
        fam.stream(jax.random.PRNGKey(0), 0)
    with pytest.raises(ValueError, match="at least one"):
        fam.stream(jax.random.PRNGKey(0), 4, sizes=())


def test_gauss_markov_stream_correlated_and_deterministic():
    """The stateful stream: successive same-size requests share geometry and
    correlate strongly (AR(1) fading), yet never repeat exactly; the whole
    stream is a pure function of the key (replay equivalence depends on it)."""
    fam = get_family("gauss_markov")
    reqs = fam.stream(jax.random.PRNGKey(6), 20, sizes=((4, 12),), corr=0.9)
    g = [np.asarray(r.g).ravel() for r in reqs]
    corrs = [np.corrcoef(g[i], g[i + 1])[0, 1] for i in range(len(g) - 1)]
    assert min(corrs) > 0.3                      # time-correlated...
    assert all(not np.array_equal(g[i], g[i + 1]) for i in range(len(g) - 1))
    # large-scale population frozen across the trace
    np.testing.assert_array_equal(np.asarray(reqs[0].c), np.asarray(reqs[-1].c))

    replay = fam.stream(jax.random.PRNGKey(6), 20, sizes=((4, 12),), corr=0.9)
    for a, b in zip(reqs, replay):
        np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))

    # corr=0 degenerates to i.i.d. redraws of the fading (fresh state each hit)
    iid = fam.stream(jax.random.PRNGKey(6), 6, sizes=((4, 12),), corr=0.0)
    c01 = np.corrcoef(np.asarray(iid[1].g).ravel(), np.asarray(iid[2].g).ravel())
    assert abs(c01[0, 1]) < 0.9

    with pytest.raises(ValueError, match="corr"):
        fam.stream(jax.random.PRNGKey(0), 2, corr=1.0)


def test_gauss_markov_stream_serves_through_loadgen():
    """The correlated stream is a drop-in workload for the serving stack:
    every request answered and feasible through the virtual-clock DES."""
    from repro.serve import AllocService, BatchPolicy, ServeConfig, run_load

    requests = get_family("gauss_markov").stream(
        jax.random.PRNGKey(8), 6, sizes=((3, 8), (4, 8))
    )
    service = AllocService(
        ServeConfig(
            policy=BatchPolicy(max_batch=2, max_wait_s=0.01), allocator=PGD_CFG
        )
    )
    result = run_load(service, requests, [0.0] * len(requests))
    assert len(result.completions) == len(requests)
    for c in result.completions:
        assert bool(feasible(requests[c.req_id], c.alloc))


# ---------------------------------------------------------------------------
# hetero_classes specifics
# ---------------------------------------------------------------------------


def test_hetero_classes_tiers_from_registry():
    classes = build_classes()
    assert len(classes) == 3
    # tiers ordered by model size: compute need, CPU and radio all ascend
    assert classes[0].c_cycles == pytest.approx(1e4)
    assert all(a.c_cycles < b.c_cycles for a, b in zip(classes, classes[1:]))
    assert all(a.f_max_hz < b.f_max_hz for a, b in zip(classes, classes[1:]))
    assert all(a.p_max_dbm < b.p_max_dbm for a, b in zip(classes, classes[1:]))

    p = get_family("hetero_classes").sample(jax.random.PRNGKey(12), N=16, K=32)
    # every drawn f_max/p_max is one of the class tiers
    assert set(np.asarray(p.f_max).tolist()) <= {c.f_max_hz for c in classes}
    tiers = np.asarray([c.p_max_w for c in classes])
    drawn = np.asarray(p.p_max, dtype=np.float64)
    assert np.all(np.min(np.abs(drawn[:, None] - tiers[None, :]), axis=1) < 1e-6)

    with pytest.raises(ValueError, match="n_classes"):
        build_classes(0)
