"""Test-session configuration.

4 host devices so the sharding/pjit tests can build miniature meshes.
(Deliberately NOT 512 — that flag belongs exclusively to launch/dryrun.py per
the build brief; smoke tests and benchmarks should see a realistic host.)
Must run before the first jax import in the test process. An explicit
``--xla_force_host_platform_device_count`` already present in XLA_FLAGS wins
— CI's sharded-path step runs the suite under 8 virtual devices.
"""
import os
import pathlib
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
    )

# src-layout import without requiring PYTHONPATH (tier-1 sets it; bare pytest
# runs and IDEs don't)
_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Prefer the real hypothesis (declared in pyproject's `test` extra); fall back
# to the vendored shim where it cannot be installed. Jax-free import, so the
# XLA_FLAGS-before-jax ordering above is preserved.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing import install_hypothesis_fallback

    install_hypothesis_fallback()
