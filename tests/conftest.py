"""Test-session configuration.

4 host devices so the sharding/pjit tests can build miniature meshes.
(Deliberately NOT 512 — that flag belongs exclusively to launch/dryrun.py per
the build brief; smoke tests and benchmarks should see a realistic host.)
Must run before the first jax import in the test process.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)
