"""Warm-start cache: dominance property suite, equivalence, concurrency.

The load-bearing invariant is DOMINANCE: a warm start is one more multi-start
candidate, selected only if strictly better under the current scenario and
accuracy model, so any cached entry — stale, wrong-scenario, or outright
garbage — can only improve or tie the objective, never hurt it. That
invariant is what lets the cache key be lossy (quantized signatures) and the
serving layer skip invalidation entirely; this suite is the gate on it:

* property sweep (hypothesis: real engine in CI, vendored shim on the
  hermetic build box) over random scenarios x adversarial cached entries,
* bit-for-bit cold==disabled equivalence at the allocator and service layers,
* padded-bucket hits == exact-shape hits on the hardened assignment,
* stale-accuracy re-scoring after `set_accuracy` (scoring path, not the
  cached objective),
* a threaded stress test racing submit/refit/set_accuracy/close with the
  cache on (no stranded futures, replay-exact answers).

Objective comparisons use a float32-round-off tolerance, additive on
``max(1, |cold|)`` — the selection scorer (batched kernel) and the test's
`system.objective` re-score agree only to ulp, and eq. 13 objectives are
O(1) and can be negative (a relative-only tolerance would flip the
inequality's direction on negative values).
"""
import threading

import hypothesis
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AllocatorConfig,
    Weights,
    sample_params,
    sample_request_stream,
    solve_batch,
    stack_params,
    tree_index,
)
from repro.core.accuracy import AccuracyFn, default_accuracy
from repro.core.allocator import ExtraStart
from repro.core.pgd import PGDConfig
from repro.core.system import objective
from repro.core.types import ShapeBucket
from repro.serve import (
    AllocService,
    BatchPolicy,
    CacheEntry,
    LadderLearner,
    RealClockDriver,
    ServeConfig,
    WarmStartCache,
    WarmStartConfig,
    batch_starts,
    entry_from_alloc,
    iters_to_converge,
    pad_start,
    request_signature,
    run_load,
    same_hardened_assignments,
)

#: shim detection: the vendored fallback has no shrinking and replays every
#: example eagerly, so the hermetic build box runs a reduced sweep; CI
#: installs the real engine and runs the full >=200-example gate
SHIM = getattr(hypothesis, "__version__", "") == "0.0.0-fedsem-shim"
N_EXAMPLES = 60 if SHIM else 200

WAIT_S = 120.0
TINY = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=40))
CFG_COLD = ServeConfig(
    policy=BatchPolicy(max_batch=2, max_wait_s=0.01), allocator=TINY
)
CFG_WARM = CFG_COLD._replace(warmstart=WarmStartConfig())
#: ONE fixed shape for the property sweep: every example reuses the same two
#: compiled programs (cold + refine), so 200 examples cost solves, not traces
PROP_N, PROP_K = 3, 6

W = Weights.ones()
ACC = default_accuracy()


def _scenario(seed: int, n=PROP_N, k=PROP_K):
    return sample_params(jax.random.PRNGKey(seed), N=n, K=k)


def _cold(params):
    return solve_batch(stack_params([params]), W, TINY)


def _obj(params, alloc0) -> float:
    return float(objective(params, W, alloc0, ACC))


def _tol(cold_obj: float) -> float:
    return 1e-5 * max(1.0, abs(cold_obj))


def _extra_from(entry_f, entry_P, entry_X, valid=1.0):
    return ExtraStart(
        f=np.asarray(entry_f, np.float32)[None],
        P=np.asarray(entry_P, np.float32)[None],
        X=np.asarray(entry_X, np.float32)[None],
        valid=np.asarray([valid], np.float32),
    )


# ---------------------------------------------------------------------------
# the dominance property sweep (the PR's headline gate)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=N_EXAMPLES, deadline=None)
@given(
    scenario_seed=st.integers(min_value=0, max_value=10_000),
    entry_seed=st.integers(min_value=0, max_value=10_000),
    entry_mode=st.sampled_from(
        ["self", "wrong_scenario", "garbage", "zeros", "scaled"]
    ),
    scale=st.floats(min_value=0.0, max_value=1e6),
)
def test_warm_dominance_property(scenario_seed, entry_seed, entry_mode, scale):
    """For ANY scenario and ANY cached entry — its own prior solution, a
    different scenario's (the adversarial wrong-key collision), random
    garbage, zeros, or wildly mis-scaled arrays — the warm objective is <=
    the cold objective up to float32 round-off, and the result is a valid
    hardened allocation."""
    params = _scenario(scenario_seed)
    base = _cold(params)
    cold_obj = _obj(params, tree_index(base.alloc, 0))

    rng = np.random.default_rng(entry_seed)
    if entry_mode == "self":
        src = base.alloc
        extra = _extra_from(src.f[0], src.P[0], src.X[0])
    elif entry_mode == "wrong_scenario":
        other = _cold(_scenario(entry_seed + 20_000))
        extra = _extra_from(other.alloc.f[0], other.alloc.P[0], other.alloc.X[0])
    elif entry_mode == "garbage":
        bad = rng.choice([np.nan, np.inf, -np.inf, 1e30, -5.0])
        extra = _extra_from(
            np.full((PROP_N,), bad),
            rng.standard_normal((PROP_N, PROP_K)) * 1e12,
            np.full((PROP_N, PROP_K), bad),
        )
    elif entry_mode == "zeros":
        extra = _extra_from(
            np.zeros((PROP_N,)), np.zeros((PROP_N, PROP_K)),
            np.zeros((PROP_N, PROP_K)),
        )
    else:  # scaled: a plausible-looking but mis-scaled prior solution
        src = base.alloc
        extra = _extra_from(
            np.asarray(src.f[0]) * scale,
            np.asarray(src.P[0]) * scale,
            np.asarray(src.X[0]),
        )

    warm = solve_batch(
        stack_params([params]), W, TINY, extra_starts=extra
    )
    warm_alloc = tree_index(warm.alloc, 0)
    warm_obj = _obj(params, warm_alloc)
    assert warm_obj <= cold_obj + _tol(cold_obj), (
        f"dominance violated ({entry_mode}): warm {warm_obj} > cold {cold_obj}"
    )
    X = np.asarray(warm_alloc.X)
    assert set(np.unique(X)) <= {0.0, 1.0}, "warm X not hardened"
    assert (X.sum(axis=0) == 1.0).all(), "subcarrier multiply-assigned"
    assert (X.sum(axis=1) >= 1.0).all(), "device left without a subcarrier"


@pytest.mark.slow
@settings(max_examples=max(20, N_EXAMPLES // 4), deadline=None)
@given(scenario_seed=st.integers(min_value=0, max_value=10_000))
def test_invalid_start_is_bitforbit_cold(scenario_seed):
    """valid=0 rows pass the cold result through BIT-FOR-BIT: selection is a
    gather over [base] + masked candidates, and base came from the unchanged
    cold program — the cold==disabled equivalence row at the allocator layer."""
    params = _scenario(scenario_seed)
    base = _cold(params)
    masked = solve_batch(
        stack_params([params]), W, TINY,
        extra_starts=_extra_from(
            np.full((PROP_N,), np.inf), np.ones((PROP_N, PROP_K)),
            np.ones((PROP_N, PROP_K)), valid=0.0,
        ),
    )
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(masked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# allocator-layer plumbing
# ---------------------------------------------------------------------------


def test_extra_starts_shape_validation():
    params = _scenario(0)
    with pytest.raises(ValueError, match="extra_starts.valid"):
        solve_batch(
            stack_params([params]), W, TINY,
            extra_starts=ExtraStart(
                f=np.zeros((1, PROP_N), np.float32),
                P=np.zeros((1, PROP_N, PROP_K), np.float32),
                X=np.zeros((1, PROP_N, PROP_K), np.float32),
                valid=np.zeros((2,), np.float32),   # wrong B
            ),
        )


def test_mixed_hit_miss_batch_isolated():
    """In one batch, a warm row must not perturb a cold row: the miss rows of
    a mixed batch equal the all-cold batch bit-for-bit."""
    scen = [_scenario(s) for s in (1, 2, 3)]
    pb = stack_params(scen)
    base = solve_batch(pb, W, TINY)
    donor = _cold(scen[0])
    extra = ExtraStart(
        f=np.stack([np.asarray(donor.alloc.f[0], np.float32)] * 3),
        P=np.stack([np.asarray(donor.alloc.P[0], np.float32)] * 3),
        X=np.stack([np.asarray(donor.alloc.X[0], np.float32)] * 3),
        valid=np.asarray([1.0, 0.0, 0.0], np.float32),
    )
    mixed = solve_batch(pb, W, TINY, extra_starts=extra)
    for i in (1, 2):   # the miss rows
        np.testing.assert_array_equal(
            np.asarray(tree_index(base.alloc, i).X),
            np.asarray(tree_index(mixed.alloc, i).X),
        )
        np.testing.assert_array_equal(
            np.asarray(tree_index(base.alloc, i).f),
            np.asarray(tree_index(mixed.alloc, i).f),
        )
    # the hit row still dominates
    cold0 = _obj(scen[0], tree_index(base.alloc, 0))
    warm0 = _obj(scen[0], tree_index(mixed.alloc, 0))
    assert warm0 <= cold0 + _tol(cold0)


# ---------------------------------------------------------------------------
# cache mechanics
# ---------------------------------------------------------------------------


def _entry(n=PROP_N, k=PROP_K, fill=0.5):
    return CacheEntry(
        f=np.full((n,), fill, np.float32),
        P=np.full((n, k), fill, np.float32),
        X=np.zeros((n, k), np.float32),
        objective=0.0,
    )


def test_cache_lru_capacity_and_stats():
    cache = WarmStartCache(WarmStartConfig(capacity=2))
    cache.put(("a",), _entry())
    cache.put(("b",), _entry())
    assert cache.get(("a",)) is not None      # refreshes a's recency
    cache.put(("c",), _entry())               # evicts b (LRU), not a
    assert cache.get(("b",)) is None
    assert cache.get(("a",)) is not None
    assert cache.get(("c",)) is not None
    s = cache.stats()
    assert s["warm_cache_size"] == 2
    assert s["warm_cache_evictions"] == 1
    assert s["warm_cache_puts"] == 3
    assert s["warm_cache_hits"] + s["warm_cache_misses"] == 4
    assert s["warm_cache_hits"] == 3


def test_signature_collides_on_similar_channels_and_splits_on_shape():
    wcfg = WarmStartConfig()
    p1 = _scenario(0)
    sig1 = request_signature(p1, W, ACC, wcfg)
    # a tiny channel perturbation (well inside one ~6 dB quantization step)
    import dataclasses

    p2 = dataclasses.replace(p1, g=p1.g * 1.01)
    assert request_signature(p2, W, ACC, wcfg) == sig1
    # a different shape can never collide (entries would not even stack)
    p3 = _scenario(0, n=PROP_N + 1, k=PROP_K + 2)
    assert request_signature(p3, W, ACC, wcfg) != sig1
    # a grossly different channel should split
    p4 = dataclasses.replace(p1, g=p1.g * 1e4)
    assert request_signature(p4, W, ACC, wcfg) != sig1


def _sig_with_gains(gains: tuple) -> tuple:
    """A synthetic signature matching `request_signature`'s layout: 7 exact
    components, the quantized gain steps at index 7, then acc/weights."""
    return (3, 6, 1.0, 2.0, 3.0, 4.0, 5.0, gains, (0.5, 0.5), (1.0, 1.0, 1.0))


def test_lookup_k1_is_get():
    """``lookup(sig, 1)`` is exactly `get`: same answer, same LRU refresh,
    same hit/miss accounting — the legacy single-candidate path."""
    cache = WarmStartCache(WarmStartConfig())
    sig = _sig_with_gains((0, 0, 0))
    assert cache.lookup(sig, 1) == []
    assert cache.stats()["warm_cache_misses"] == 1
    e = _entry()
    cache.put(sig, e)
    assert cache.lookup(sig, 1) == [e]
    assert cache.stats()["warm_cache_hits"] == 1


def test_lookup_topk_ranks_neighbours_by_gain_distance():
    """k > 1: the exact hit leads, then neighbours — same signature except
    the gain steps — ranked by L1 step distance; entries differing in any
    OTHER component (shape, accuracy, weights) are never candidates."""
    cache = WarmStartCache(WarmStartConfig(top_k=3))
    exact = _sig_with_gains((0, 0, 0))
    near = _sig_with_gains((1, 0, 0))      # L1 distance 1
    far = _sig_with_gains((3, -2, 0))      # L1 distance 5
    other_acc = exact[:8] + ((0.9, 0.1),) + exact[9:]
    e_exact, e_near, e_far, e_other = (_entry(fill=v) for v in (0.1, 0.2, 0.3, 0.4))
    cache.put(far, e_far)
    cache.put(near, e_near)
    cache.put(exact, e_exact)
    cache.put(other_acc, e_other)
    hits = cache.lookup(exact)              # k defaults to cfg.top_k
    assert hits == [e_exact, e_near, e_far]
    assert cache.stats()["warm_cache_hits"] == 1   # ONE lookup, one hit
    # k caps the candidate list
    assert cache.lookup(exact, 2) == [e_exact, e_near]
    # neighbours alone still count as a (speculative) hit
    cache2 = WarmStartCache(WarmStartConfig(top_k=3))
    cache2.put(near, e_near)
    assert cache2.lookup(exact) == [e_near]
    assert cache2.stats()["warm_cache_hits"] == 1
    # empty cache: one miss for the whole lookup
    cache3 = WarmStartCache(WarmStartConfig(top_k=3))
    assert cache3.lookup(exact) == []
    assert cache3.stats()["warm_cache_misses"] == 1


def test_lookup_neighbours_do_not_refresh_recency():
    """A neighbour read must not refresh the neighbour's LRU slot — it is a
    speculative candidate, not a use of its own key."""
    cache = WarmStartCache(WarmStartConfig(capacity=2, top_k=2))
    near = _sig_with_gains((1, 0, 0))
    exact = _sig_with_gains((0, 0, 0))
    cache.put(near, _entry(fill=0.2))
    cache.put(exact, _entry(fill=0.1))
    cache.lookup(exact)                     # touches `near` as a neighbour
    cache.put(_sig_with_gains((5, 5, 5)), _entry(fill=0.3))
    assert cache.get(near) is None          # evicted: recency NOT refreshed
    assert cache.get(exact) is not None


def test_batch_starts_multi_candidate_shapes():
    """Candidate lists pad to a (B, C) axis with per-candidate valid; a
    single bare `CacheEntry` (which IS a tuple — the regression) stays the
    legacy (B,) layout."""
    from repro.core import pad_params

    padded = pad_params(_scenario(0), ShapeBucket(PROP_N, PROP_K))
    # bare entries only -> legacy (B,) layout even when the service's k > 1
    legacy = batch_starts([_entry(), None], [padded] * 2)
    assert legacy.valid.shape == (2,)
    assert legacy.f.shape == (2, PROP_N)
    # a two-candidate slot, padded to k=3 programs-stay-bounded width
    extra = batch_starts(
        [[_entry(fill=0.2), _entry(fill=0.4)], None], [padded] * 2, k=3
    )
    assert extra.valid.shape == (2, 3)
    assert extra.f.shape == (2, 3, PROP_N)
    np.testing.assert_array_equal(
        np.asarray(extra.valid), [[1.0, 1.0, 0.0], [0.0, 0.0, 0.0]]
    )
    np.testing.assert_array_equal(extra.f[0, 1], _entry(fill=0.4).f)
    # miss slots carry the inert placeholder (f = f_max/2, P = X = 0)
    np.testing.assert_array_equal(
        extra.f[1, 0], 0.5 * np.asarray(padded.f_max, np.float32)
    )
    np.testing.assert_array_equal(extra.P[1], 0.0)


def test_single_candidate_axis_is_bitforbit_legacy():
    """(B, 1) candidate-axis ExtraStart == the legacy (B,) layout through
    `solve_batch`, every leaf — the refine program's C=1 compatibility row."""
    params = _scenario(21)
    donor = _cold(params)
    f0 = np.asarray(donor.alloc.f[0], np.float32)
    P0 = np.asarray(donor.alloc.P[0], np.float32)
    X0 = np.asarray(donor.alloc.X[0], np.float32)
    legacy = solve_batch(
        stack_params([params]), W, TINY, extra_starts=_extra_from(f0, P0, X0)
    )
    multi = solve_batch(
        stack_params([params]), W, TINY,
        extra_starts=ExtraStart(
            f=f0[None, None], P=P0[None, None], X=X0[None, None],
            valid=np.ones((1, 1), np.float32),
        ),
    )
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(multi)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@settings(max_examples=max(20, N_EXAMPLES // 4), deadline=None)
@given(
    scenario_seed=st.integers(min_value=0, max_value=10_000),
    donor_seed=st.integers(min_value=0, max_value=10_000),
    garbage_mode=st.sampled_from(["nan", "scaled", "zeros"]),
)
def test_topk_candidates_dominance_property(scenario_seed, donor_seed, garbage_mode):
    """Dominance extends per candidate: a (B, C) start carrying the row's own
    prior solution PLUS an adversarial neighbour (garbage / mis-scaled /
    zeros, as `lookup` might speculatively attach) still answers <= cold and
    hardened — no candidate can hurt, however wrong."""
    params = _scenario(scenario_seed)
    base = _cold(params)
    cold_obj = _obj(params, tree_index(base.alloc, 0))
    f0 = np.asarray(base.alloc.f[0], np.float32)
    P0 = np.asarray(base.alloc.P[0], np.float32)
    X0 = np.asarray(base.alloc.X[0], np.float32)
    if garbage_mode == "nan":
        f1, P1, X1 = np.full_like(f0, np.nan), P0 * 1e12, np.full_like(X0, np.nan)
    elif garbage_mode == "scaled":
        donor = _cold(_scenario(donor_seed + 20_000))
        f1 = np.asarray(donor.alloc.f[0], np.float32) * 1e6
        P1 = np.asarray(donor.alloc.P[0], np.float32) * 1e6
        X1 = np.asarray(donor.alloc.X[0], np.float32)
    else:
        f1, P1, X1 = np.zeros_like(f0), np.zeros_like(P0), np.zeros_like(X0)
    extra = ExtraStart(
        f=np.stack([f0, f1])[None],
        P=np.stack([P0, P1])[None],
        X=np.stack([X0, X1])[None],
        valid=np.ones((1, 2), np.float32),
    )
    warm = solve_batch(stack_params([params]), W, TINY, extra_starts=extra)
    warm_alloc = tree_index(warm.alloc, 0)
    warm_obj = _obj(params, warm_alloc)
    assert warm_obj <= cold_obj + _tol(cold_obj), (
        f"top-k dominance violated ({garbage_mode}): {warm_obj} > {cold_obj}"
    )
    X = np.asarray(warm_alloc.X)
    assert set(np.unique(X)) <= {0.0, 1.0}
    assert (X.sum(axis=0) == 1.0).all()
    assert (X.sum(axis=1) >= 1.0).all()


def test_service_topk_attaches_neighbours_and_bounds_programs():
    """End to end with ``top_k=2``: a drifted re-request hits its neighbour,
    dominance holds, and the executable cache holds at most TWO refine
    programs for the bucket (C=1 legacy + C=top_k) however the fill mix
    varies."""
    import dataclasses

    wcfg = WarmStartConfig(top_k=2, gain_quant_db=3.0)
    svc = AllocService(CFG_COLD._replace(warmstart=wcfg))
    params = _stream(1, seed=17, sizes=((3, 8),))[0]
    svc.submit(params)
    first, _ = svc.drain(now=0.0)
    # drift the channel past one quantization step: exact key misses, the
    # neighbour search finds the recorded entry
    drifted = dataclasses.replace(params, g=params.g * 10.0 ** (4.5 / 10.0))
    assert request_signature(drifted, W, ACC, wcfg) != request_signature(
        params, W, ACC, wcfg
    )
    svc.submit(drifted)
    second, _ = svc.drain(now=1.0)
    assert second[0].warm_hit
    cold_svc = AllocService(CFG_COLD, executables=svc.executables)
    cold_svc.submit(drifted)
    cold_done, _ = cold_svc.drain(now=0.0)
    o_cold = cold_done[0].objective
    assert second[0].objective <= o_cold + _tol(o_cold)
    refine_keys = [k for k in svc.executables if "warm-refine" in k]
    assert len(refine_keys) <= 2
    cands = {k[-1] for k in refine_keys}
    assert cands <= {1, 2}


def test_iters_to_converge():
    assert iters_to_converge([5.0, 2.0, 1.0, 1.0], rtol=1e-3) == 3
    assert iters_to_converge([1.0, 1.0, 1.0], rtol=1e-3) == 1
    assert iters_to_converge([3.0, np.nan, 2.0], rtol=1e-3) == 3
    assert iters_to_converge([np.inf, 1.0], rtol=1e-3) == 2
    assert iters_to_converge([2.0, 1.0, np.nan], rtol=1e-3) == 3


# ---------------------------------------------------------------------------
# service layer: cold==disabled, padded==exact, recording
# ---------------------------------------------------------------------------


def _stream(n=6, seed=7, sizes=((3, 8), (4, 8))):
    return sample_request_stream(jax.random.PRNGKey(seed), n, sizes=sizes)


def test_service_empty_cache_is_bitforbit_disabled():
    """One drained batch: every request misses (nothing was ever completed),
    so the warm service must run the plain cold executable and answer
    bit-for-bit like a warmstart=None service."""
    requests = _stream()
    cold_svc = AllocService(CFG_COLD)
    for p in requests:
        cold_svc.submit(p)
    cold_done, _ = cold_svc.drain(now=0.0)

    warm_svc = AllocService(CFG_WARM, executables=cold_svc.executables)
    for p in requests:
        warm_svc.submit(p)
    warm_done, _ = warm_svc.drain(now=0.0)

    assert warm_svc.warm_cache.stats()["warm_cache_hits"] == 0
    assert same_hardened_assignments(cold_done, warm_done)
    cold_f = {c.req_id: np.asarray(c.alloc.f) for c in cold_done}
    for c in warm_done:
        np.testing.assert_array_equal(np.asarray(c.alloc.f), cold_f[c.req_id])
        assert not c.warm_hit and c.warm_start is None


def test_padded_bucket_hit_matches_exact_shape_hit():
    """The same cached entry attached to the same scenario must produce the
    same hardened assignment whether the request solves at its exact shape or
    padded into a bucket (`pad_start` mask-awareness)."""
    params = _stream(1, seed=3, sizes=((3, 8),))[0]
    donor = _cold(_scenario(99, n=3, k=8))
    entry = entry_from_alloc(tree_index(donor.alloc, 0))

    exact_svc = AllocService(CFG_WARM._replace(buckets=None))
    exact_svc.submit(params, warm_start=entry)
    exact_done, _ = exact_svc.drain(now=0.0)

    padded_svc = AllocService(
        CFG_WARM._replace(buckets=(ShapeBucket(6, 12),))
    )
    padded_svc.submit(params, warm_start=entry)
    padded_done, _ = padded_svc.drain(now=0.0)

    np.testing.assert_array_equal(
        np.asarray(exact_done[0].alloc.X), np.asarray(padded_done[0].alloc.X)
    )
    assert exact_done[0].warm_hit and padded_done[0].warm_hit


def test_pad_start_shapes_and_mask():
    from repro.core import pad_params

    params = _scenario(5)
    padded = pad_params(params, ShapeBucket(PROP_N + 2, PROP_K + 3))
    entry = _entry(fill=0.25)
    f, P, X = pad_start(entry, padded)
    assert f.shape == (PROP_N + 2,)
    assert P.shape == X.shape == (PROP_N + 2, PROP_K + 3)
    np.testing.assert_array_equal(P[PROP_N:], 0.0)
    np.testing.assert_array_equal(P[:, PROP_K:], 0.0)
    np.testing.assert_array_equal(f[:PROP_N], entry.f)


def test_service_records_and_reuses_solutions():
    """Second identical request hits the entry recorded by the first flush
    and the answer still matches (same scenario => the cached optimum rides
    along; dominance makes it a tie or better)."""
    params = _stream(1, seed=11, sizes=((3, 8),))[0]
    svc = AllocService(CFG_WARM)
    svc.submit(params)
    first, _ = svc.drain(now=0.0)
    assert svc.warm_cache.stats()["warm_cache_puts"] == 1
    svc.submit(params)
    second, _ = svc.drain(now=1.0)
    assert second[0].warm_hit
    o1, o2 = first[0].objective, second[0].objective
    assert o2 <= o1 + _tol(o1)


def test_batch_starts_all_miss_returns_none():
    params = _scenario(0)
    from repro.core import pad_params

    padded = pad_params(params, ShapeBucket(PROP_N, PROP_K))
    assert batch_starts([None, None], [padded, padded]) is None
    extra = batch_starts([None, _entry()], [padded, padded])
    assert extra is not None
    np.testing.assert_array_equal(np.asarray(extra.valid), [0.0, 1.0])


# ---------------------------------------------------------------------------
# set_accuracy x stale cache entries: the scoring path is pinned
# ---------------------------------------------------------------------------


def test_stale_entry_rescored_under_new_accuracy():
    """After an A(rho) swap, a hit recorded under the OLD model must be
    re-scored (and re-selected) under the NEW one: the completion's objective
    is the new model's value of the returned allocation, not the cached
    number, and dominance holds against a cold solve under the new model."""
    # coarse acc quantization so old/new models share a signature while
    # differing materially — the staleness lives in the VALUE, not the key
    wcfg = WarmStartConfig(acc_digits=1)
    cfg = CFG_COLD._replace(warmstart=wcfg)
    params = _stream(1, seed=13, sizes=((3, 8),))[0]

    import jax.numpy as jnp

    acc_old = AccuracyFn(a=jnp.float32(0.64), b=jnp.float32(0.40))
    acc_new = AccuracyFn(a=jnp.float32(0.58), b=jnp.float32(0.44))
    assert request_signature(params, W, acc_old, wcfg) == request_signature(
        params, W, acc_new, wcfg
    )

    svc = AllocService(cfg)
    svc.set_accuracy(acc_old)
    svc.submit(params)
    old_done, _ = svc.drain(now=0.0)
    stale_obj = old_done[0].objective

    svc.set_accuracy(acc_new)
    svc.submit(params)
    new_done, _ = svc.drain(now=1.0)
    assert new_done[0].warm_hit, "old-model entry should hit the shared key"

    # pinned: the reported objective is the NEW model's score of the answer
    rescored = float(objective(params, W, new_done[0].alloc, acc_new))
    np.testing.assert_allclose(new_done[0].objective, rescored, rtol=1e-4)

    # and it dominates a cold solve under the new model
    cold_svc = AllocService(CFG_COLD, executables=svc.executables)
    cold_svc.set_accuracy(acc_new)
    cold_svc.submit(params)
    cold_done, _ = cold_svc.drain(now=0.0)
    assert (
        new_done[0].objective
        <= cold_done[0].objective + _tol(cold_done[0].objective)
    )
    # the two models genuinely disagree, so a lazily-cached old score would
    # have been caught by the pin above
    assert abs(stale_obj - float(objective(params, W, old_done[0].alloc, acc_new))) > 1e-4


# ---------------------------------------------------------------------------
# concurrency stress: submitters racing refit/set_accuracy/close, cache on
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_driver_stress_with_cache_refit_accuracy_close():
    """Threaded submitters race `refit()`, `set_accuracy()` (a value-identical
    swap, so answers stay deterministic) and finally `close()` with the cache
    enabled: no stranded futures, and every answer matches a virtual-clock
    replay that re-injects the recorded warm starts (cache-corruption gate —
    a torn entry or a mis-attached hit would change some request's X)."""
    n_threads, per_thread = 3, 4
    streams = [
        _stream(per_thread, seed=100 + t, sizes=((3, 8), (4, 8)))
        for t in range(n_threads)
    ]
    service = AllocService(CFG_WARM)
    service.warmup(streams[0])
    driver = RealClockDriver(service, ladder=LadderLearner(min_samples=1))

    results: dict[int, tuple] = {}
    lock = threading.Lock()
    errors: list[BaseException] = []

    def submitter(t):
        try:
            futs = [(p, driver.submit(p)) for p in streams[t]]
            for p, fut in futs:
                c = fut.result(timeout=WAIT_S)
                with lock:
                    results[c.req_id] = (p, c)
        except BaseException as e:   # noqa: BLE001 - surfaced below
            errors.append(e)

    threads = [
        threading.Thread(target=submitter, args=(t,)) for t in range(n_threads)
    ]
    for th in threads:
        th.start()
    # race the control plane while submissions are in flight
    for _ in range(5):
        driver.refit()
        service.set_accuracy(default_accuracy())
    for th in threads:
        th.join(timeout=WAIT_S)
    assert not any(th.is_alive() for th in threads), "submitter hung"
    driver.close(timeout=WAIT_S)
    assert not errors, errors
    n_total = n_threads * per_thread
    assert len(results) == n_total, "stranded futures"

    # replay on the virtual clock with the RECORDED warm starts (fresh
    # cache-disabled service: cache contents are timing-dependent, the
    # recorded starts are the ground truth of what each request rode)
    ordered = [results[i] for i in range(n_total)]
    replay = run_load(
        AllocService(CFG_COLD, executables=service.executables),
        [p for p, _ in ordered],
        [0.0] * n_total,
        warm_starts=[c.warm_start for _, c in ordered],
    )
    assert same_hardened_assignments(
        [c for _, c in ordered], replay.completions
    )


def test_driver_summary_includes_cache_stats():
    requests = _stream(2)
    service = AllocService(CFG_WARM)
    with RealClockDriver(service) as driver:
        futs = [driver.submit(p) for p in requests]
        for f in futs:
            f.result(timeout=WAIT_S)
        s = driver.summary()
    assert "warm_cache_hits" in s and "warm_cache_puts" in s
    assert s["warm_cache_puts"] == len(requests)
