"""End-to-end tests for Alg. A2 and the baselines (paper §V claims)."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AllocatorConfig, Weights, sample_params, solve
from repro.core import baselines as B
from repro.core.allocator import harden_x, repair_rate_floor
from repro.core.p5 import P5Config, r_min
from repro.core.system import device_rate, feasible, report


@pytest.fixture(scope="module")
def params():
    return sample_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module", params=["sca", "pgd"])
def result(request, params):
    return request.param, solve(params, Weights.ones(), AllocatorConfig(inner=request.param))


def test_allocator_feasible(params, result):
    _, res = result
    assert bool(feasible(params, res.alloc))


def test_allocator_beats_equal(params, result):
    """Fig. 4's headline claim: proposed < Equal Allocation in objective."""
    _, res = result
    w = Weights.ones()
    obj = float(report(params, w, res.alloc)["objective"])
    eq = float(report(params, w, B.equal_allocation(params))["objective"])
    assert obj < eq - 0.5


def test_allocator_beats_all_baselines(params):
    """Fig. 4: proposed (joint) <= every baseline.

    Our comm-only baseline shares the PGD engine with the proposed solver
    (it is *stronger* than the paper's), so proposed-with-PGD-inner must beat
    it strictly; the paper-faithful SCA inner gets a 5% solver-noise margin.
    """
    w = Weights.ones()
    obj_pgd = float(report(params, w, solve(params, w, AllocatorConfig(inner="pgd")).alloc)["objective"])
    obj_sca = float(report(params, w, solve(params, w, AllocatorConfig(inner="sca")).alloc)["objective"])
    key = jax.random.PRNGKey(3)
    others = {
        "equal": B.equal_allocation(params),
        "comm_only": B.comm_opt_only(params, w, key),
        "comp_only": B.comp_opt_only(params, w),
        "random": B.random_allocation(params, key),
    }
    for name, alloc in others.items():
        base = float(report(params, w, alloc)["objective"])
        assert obj_pgd <= base + 1e-3, f"proposed(pgd) {obj_pgd} worse than {name} {base}"
        assert obj_sca <= base + 0.05 * abs(base) + 1e-3, (
            f"proposed(sca) {obj_sca} much worse than {name} {base}"
        )


def test_x_binary_after_hardening(params, result):
    _, res = result
    X = np.asarray(res.alloc.X)
    assert set(np.unique(X)).issubset({0.0, 1.0})
    assert (X.sum(0) <= 1).all()          # (13d)
    assert (X.sum(1) >= 1).all()          # every device got a subcarrier


def test_harden_x_preserves_every_device():
    X = jnp.asarray([[0.9, 0.8, 0.7], [0.1, 0.0, 0.0]])
    Xb = harden_x(X, 2, 3)
    assert float(Xb.sum()) == 3.0
    assert bool(jnp.all(Xb.sum(1) >= 1))


def test_repair_rate_floor(params):
    X = jnp.zeros((params.N, params.K)).at[jnp.arange(params.K) % params.N,
                                           jnp.arange(params.K)].set(1.0)
    P = X * 1e-6  # absurdly low power -> rates below floor
    rmin = jnp.full((params.N,), 2e6)
    P2 = repair_rate_floor(params, P, X, rmin)
    r = device_rate(params, P2, X)
    reachable = device_rate(params, X * params.p_max[:, None] / jnp.maximum(X.sum(-1, keepdims=True), 1), X) >= rmin
    assert bool(jnp.all(jnp.where(reachable, r >= rmin * 0.999, True)))
    assert bool(jnp.all(jnp.sum(P2, -1) <= params.p_max * 1.001))


def test_convergence_trace(params, result):
    """Alg. A2 converges: last-step improvement is small vs total change."""
    _, res = result
    tr = np.asarray(res.trace)
    assert np.isfinite(tr).all()
    total = abs(tr[-1] - tr[0]) + 1e-6
    assert abs(tr[-1] - tr[-2]) <= 0.35 * total + 0.15


def test_kappa1_monotonicity():
    """Fig. 3(a): larger kappa1 => less energy (weak monotonicity)."""
    params = sample_params(jax.random.PRNGKey(1))
    energies = []
    for k1 in [0.3, 3.0]:
        w = Weights(jnp.float32(k1), jnp.float32(1.0), jnp.float32(1.0))
        res = solve(params, w, AllocatorConfig(inner="sca"))
        energies.append(float(report(params, w, res.alloc)["energy_total"]))
    assert energies[1] <= energies[0] * 1.1


def test_kappa3_raises_rho():
    """Fig. 8(a): larger kappa3 => larger compression rate rho."""
    params = sample_params(jax.random.PRNGKey(2))
    rhos = []
    for k3 in [0.02, 5.0]:
        w = Weights(jnp.float32(1.0), jnp.float32(1.0), jnp.float32(k3))
        res = solve(params, w, AllocatorConfig(inner="sca"))
        rhos.append(float(res.alloc.rho))
    assert rhos[1] >= rhos[0]


@pytest.mark.slow
@hypothesis.settings(max_examples=5, deadline=None)
@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000))
def test_allocator_property_feasible_any_channel(seed):
    """Property: any sampled scenario yields a feasible, finite allocation."""
    params = sample_params(jax.random.PRNGKey(seed), N=4, K=12)
    w = Weights.ones()
    res = solve(params, w, AllocatorConfig(inner="pgd"))
    rep = report(params, w, res.alloc)
    assert np.isfinite(float(rep["objective"]))
    assert bool(feasible(params, res.alloc))


def test_vmap_over_channels():
    keys = jax.random.split(jax.random.PRNGKey(11), 4)
    params_b = jax.vmap(lambda k: sample_params(k, N=4, K=12))(keys)
    w = Weights.ones()
    objs = jax.vmap(
        lambda p: report(p, w, solve(p, w, AllocatorConfig(inner="pgd")).alloc)["objective"]
    )(params_b)
    assert objs.shape == (4,) and bool(jnp.all(jnp.isfinite(objs)))
