"""Scenario-parallel sharding: sharded `solve_batch` == single-device
`solve_batch` (exact hardened X, aggregate rho/objective tolerances), batch
padding for non-divisible meshes, and the sharded serving path.

Runs on the conftest's forced host devices (4 locally; CI adds a step under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), so the sharded
executable really partitions over multiple devices on CPU.

Tolerance contract (same as the padded-solve tests): the hardened discrete
assignment must match EXACTLY; continuous leaves are compared through
aggregate rho/objective, never per-entry P — fp reduction reordering across
device partitions enters at denormal scale and Adam amplifies it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AllocatorConfig,
    Weights,
    pad_batch,
    sample_params,
    sample_params_batch,
    scenario_mesh,
    shard_batch,
    slice_batch,
    solve_batch,
    stack_weights,
    tree_index,
)
from repro.core.distribute import SCENARIO_AXIS, round_up, scenario_sharding
from repro.core.pgd import PGDConfig
from repro.core.system import feasible, objective
from repro.serve import AllocService, BatchPolicy, ServeConfig

W = Weights.ones()
CFG = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))


def _assert_batches_equivalent(params_batch, got, ref, weights=None):
    """Exact hardened X; rho and per-scenario objective to fp-chaos tol."""
    np.testing.assert_array_equal(np.asarray(got.alloc.X), np.asarray(ref.alloc.X))
    np.testing.assert_allclose(
        np.asarray(got.alloc.rho), np.asarray(ref.alloc.rho), rtol=5e-3
    )
    b = got.alloc.rho.shape[0]
    for i in range(b):
        p = tree_index(params_batch, i)
        w = tree_index(weights, i) if weights is not None else W
        np.testing.assert_allclose(
            float(objective(p, w, tree_index(got.alloc, i))),
            float(objective(p, w, tree_index(ref.alloc, i))),
            rtol=1e-2,
        )


def test_scenario_mesh_covers_local_devices():
    mesh = scenario_mesh()
    assert mesh.size == jax.device_count() > 1  # conftest forces >= 4
    assert mesh.axis_names == (SCENARIO_AXIS,)


def test_shard_batch_splits_leading_axis():
    mesh = scenario_mesh()
    pb = sample_params_batch(jax.random.PRNGKey(0), mesh.size * 2, N=4, K=8)
    sharded = shard_batch(pb, mesh)
    assert sharded.g.sharding == scenario_sharding(mesh)
    # each device holds B/device_count scenarios, whole on trailing axes
    shard_shapes = {s.data.shape for s in sharded.g.addressable_shards}
    assert shard_shapes == {(2, 4, 8)}


def test_pad_slice_batch_roundtrip():
    pb = sample_params_batch(jax.random.PRNGKey(1), 3, N=4, K=8)
    padded = pad_batch(pb, 8)
    assert padded.g.shape == (8, 4, 8)
    # tail replicas of the last scenario, real block untouched
    np.testing.assert_array_equal(np.asarray(padded.g[:3]), np.asarray(pb.g))
    np.testing.assert_array_equal(np.asarray(padded.g[7]), np.asarray(pb.g[2]))
    back = slice_batch(padded, 3)
    np.testing.assert_array_equal(np.asarray(back.g), np.asarray(pb.g))
    with pytest.raises(ValueError, match="shrink"):
        pad_batch(pb, 2)


def test_sharded_solve_batch_matches_single_device():
    mesh = scenario_mesh()
    pb = sample_params_batch(jax.random.PRNGKey(2), mesh.size * 2, N=4, K=8)
    ref = solve_batch(pb, W, CFG)
    got = solve_batch(pb, W, CFG, mesh=mesh)
    _assert_batches_equivalent(pb, got, ref)
    for i in range(pb.g.shape[0]):
        assert bool(feasible(tree_index(pb, i), tree_index(got.alloc, i)))


def test_sharded_solve_batch_pads_non_divisible():
    mesh = scenario_mesh()
    b = mesh.size + 1                        # forces the pad/slice path
    pb = sample_params_batch(jax.random.PRNGKey(3), b, N=4, K=8)
    got = solve_batch(pb, W, CFG, mesh=mesh)
    assert got.alloc.rho.shape == (b,)       # sliced back to the real batch
    ref = solve_batch(pb, W, CFG)
    _assert_batches_equivalent(pb, got, ref)


def test_sharded_weights_batched():
    mesh = scenario_mesh()
    p = sample_params(jax.random.PRNGKey(4), N=4, K=8)
    ws = [
        Weights(jnp.float32(1.0 + i), jnp.float32(1.0), jnp.float32(1.0))
        for i in range(mesh.size)
    ]
    pb = jax.tree.map(lambda x: jnp.stack([x] * mesh.size), p)
    wb = stack_weights(ws)
    ref = solve_batch(pb, wb, CFG, weights_batched=True)
    got = solve_batch(pb, wb, CFG, weights_batched=True, mesh=mesh)
    _assert_batches_equivalent(pb, got, ref, weights=wb)


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------

SHARD_SERVE = ServeConfig(
    policy=BatchPolicy(max_batch=2, max_wait_s=0.01),
    allocator=AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=40)),
    shard_batch=True,
)


def test_sharded_service_slots_and_cache():
    """shard_batch sizes bucket slots to device_count x max_batch, and the
    executable cache keys on the mesh (a shared dict must never hand a
    single-device program to a sharded service or vice versa)."""
    n_dev = jax.device_count()
    sharded = AllocService(SHARD_SERVE)
    assert sharded.mesh is not None and sharded.mesh.size == n_dev
    assert sharded._full_slots == 2 * n_dev
    assert sharded.batcher.policy.max_batch == 2 * n_dev
    p = sample_params(jax.random.PRNGKey(5), N=4, K=8)
    sharded.warmup([p])
    assert sharded.metrics.cache_misses == 1
    single = AllocService(
        SHARD_SERVE._replace(shard_batch=False), executables=sharded.executables
    )
    single.warmup([p])
    assert single.metrics.cache_misses == 1     # same bucket/cfg, no mesh -> miss
    assert len(sharded.executables) == 2


def test_sharded_service_matches_unsharded():
    """The same requests answered by a sharded and an unsharded service get
    identical hardened assignments (the batch axis split is invisible)."""
    requests = [sample_params(jax.random.PRNGKey(10 + i), N=4, K=8) for i in range(3)]
    results = {}
    for name, shard in (("sharded", True), ("single", False)):
        service = AllocService(SHARD_SERVE._replace(shard_batch=shard))
        for i, p in enumerate(requests):
            service.submit(p, now=0.0)
        done, _ = service.drain(now=0.0)
        results[name] = {c.req_id: c.alloc for c in done}
    assert sorted(results["sharded"]) == sorted(results["single"]) == [0, 1, 2]
    for rid, p in enumerate(requests):
        a, b = results["sharded"][rid], results["single"][rid]
        np.testing.assert_array_equal(np.asarray(a.X), np.asarray(b.X))
        np.testing.assert_allclose(
            float(objective(p, W, a)), float(objective(p, W, b)), rtol=1e-2
        )
        assert bool(feasible(p, a))
