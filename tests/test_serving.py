"""Serving-loop behaviour tests (continuous batching over a request queue)."""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.serve import ServeLoop
from repro.models import model as M
from repro.models.config import smoke_variant


def test_serve_loop_completes_all_requests():
    cfg = smoke_variant(get_config("qwen2_5_3b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    loop = ServeLoop(cfg, params, batch_slots=2, max_len=64)
    key = jax.random.PRNGKey(1)
    prompts = [
        [int(t) for t in jax.random.randint(jax.random.fold_in(key, i), (4,), 0, cfg.vocab)]
        for i in range(5)  # more requests than slots -> queue refill path
    ]
    results, stats = loop.run(prompts, max_new=6)
    assert set(results) == set(range(5))
    assert all(len(v) == 6 for v in results.values())
    assert all(0 <= t < cfg.vocab for v in results.values() for t in v)
    assert stats["steps"] > 0


def test_serve_loop_greedy_deterministic():
    cfg = smoke_variant(get_config("gemma2_2b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3, 4]]
    out1, _ = ServeLoop(cfg, params, 1, max_len=32).run([list(prompts[0])], max_new=5)
    out2, _ = ServeLoop(cfg, params, 1, max_len=32).run([list(prompts[0])], max_new=5)
    assert out1[0] == out2[0]


def test_allocator_auto_inner():
    """inner='auto' never does worse than either single inner."""
    from repro.core import AllocatorConfig, Weights, sample_params, solve
    from repro.core.system import report

    params = sample_params(jax.random.PRNGKey(5), N=4, K=12)
    w = Weights.ones()
    objs = {}
    for inner in ("sca", "pgd", "auto"):
        res = solve(params, w, AllocatorConfig(inner=inner))
        objs[inner] = float(report(params, w, res.alloc)["objective"])
    assert objs["auto"] <= min(objs["sca"], objs["pgd"]) + 1e-4
