"""Real-clock driver + learned ladder: equivalence, drain, backpressure.

CI-safe on a 2-core box by construction: tiny allocator config, generous
completion timeouts, and NO assertions on latency/throughput values — only
on *what* was answered (exact hardened X, per the padded-solve tolerance
contract), that shutdown drains everything, and that the bounded admission
queue rejects/blocks instead of growing.
"""
import queue
import threading

import jax
import numpy as np
import pytest

from repro.core import AllocatorConfig, sample_params, sample_request_stream
from repro.core.pgd import PGDConfig
from repro.core.types import DEFAULT_BUCKETS, ShapeBucket
from repro.serve import (
    AdmissionQueueFull,
    AllocService,
    BatchPolicy,
    DriverClosed,
    DriverConfig,
    LadderLearner,
    RealClockDriver,
    ServeConfig,
    learn_buckets,
    padded_area_waste,
    run_load,
)

#: generous wall-clock allowance for one batched solve on a loaded CI box
WAIT_S = 120.0
TINY = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=40))
CFG = ServeConfig(policy=BatchPolicy(max_batch=2, max_wait_s=0.01), allocator=TINY)


def _stream(n=6, seed=7):
    return sample_request_stream(jax.random.PRNGKey(seed), n, sizes=((3, 8), (4, 8)))


# ---------------------------------------------------------------------------
# equivalence: real-clock driver == virtual-clock loadgen
# ---------------------------------------------------------------------------


def test_driver_matches_virtual_loadgen_exact_x():
    """Same stream => identical req_id -> hardened X mapping. Equivalence is
    structural (both fronts drive the same sans-IO service single-threaded),
    so X must agree EXACTLY even though real-clock batch boundaries differ."""
    requests = _stream()
    ref_service = AllocService(CFG)
    ref_service.warmup(requests)
    ref = run_load(ref_service, requests, [0.0] * len(requests))

    service = AllocService(CFG, executables=ref_service.executables)
    with RealClockDriver(service) as driver:
        futures = [driver.submit(p) for p in requests]
        done = [f.result(timeout=WAIT_S) for f in futures]

    assert sorted(c.req_id for c in done) == list(range(len(requests)))
    ref_x = {c.req_id: np.asarray(c.alloc.X) for c in ref.completions}
    for c in done:
        np.testing.assert_array_equal(np.asarray(c.alloc.X), ref_x[c.req_id])
        # exact shapes back, like the virtual path
        assert c.alloc.P.shape == (requests[c.req_id].N, requests[c.req_id].K)
        np.testing.assert_allclose(
            float(c.alloc.rho),
            float({r.req_id: r for r in ref.completions}[c.req_id].alloc.rho),
            rtol=5e-3,
        )


def test_driver_multithreaded_submitters_all_answered():
    """Concurrent caller threads (the real serving shape): every submit gets
    its own scenario's answer back (exact shape), none are lost."""
    requests = _stream(8)
    service = AllocService(CFG)
    service.warmup(requests)
    results: dict[int, object] = {}

    def client(idx):
        fut = driver.submit(requests[idx])
        results[idx] = fut.result(timeout=WAIT_S)

    with RealClockDriver(service) as driver:
        threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=WAIT_S)
    assert sorted(results) == list(range(8))
    for i, c in results.items():
        assert c.alloc.P.shape == (requests[i].N, requests[i].K)


# ---------------------------------------------------------------------------
# shutdown drains everything
# ---------------------------------------------------------------------------


def test_close_drains_pending_requests():
    """Requests still waiting in never-full, never-due buckets must be
    answered by the graceful drain, not dropped."""
    requests = _stream(3)
    # max_wait so large nothing goes due on its own; max_batch larger than
    # the stream so nothing fills either — only drain can flush
    cfg = CFG._replace(policy=BatchPolicy(max_batch=8, max_wait_s=1e6))
    service = AllocService(cfg)
    service.warmup(requests)
    driver = RealClockDriver(service)
    futures = [driver.submit(p) for p in requests]
    driver.close(timeout=WAIT_S)
    done = [f.result(timeout=0.0) for f in futures]    # resolved by the drain
    assert sorted(c.req_id for c in done) == [0, 1, 2]
    assert service.pending() == 0
    assert len(driver.completions) == 3


def test_solver_thread_error_fails_futures_and_close_raises():
    """A crash in the solver thread must not strand callers: every in-flight
    future fails with the error, and close() re-raises instead of reporting
    a clean drain."""
    service = AllocService(CFG)

    def boom(now):
        raise RuntimeError("synthetic flush failure")

    service.flush_due = boom
    driver = RealClockDriver(service)
    fut = driver.submit(sample_params(jax.random.PRNGKey(0), N=4, K=8))
    with pytest.raises(RuntimeError, match="synthetic flush failure"):
        fut.result(timeout=WAIT_S)
    with pytest.raises(RuntimeError, match="solver thread died"):
        driver.close(timeout=WAIT_S)


def test_completion_log_is_bounded():
    """driver.completions is a rolling window (futures carry every answer),
    so an indefinitely running driver cannot leak through its own log."""
    requests = _stream(4)
    service = AllocService(CFG)
    service.warmup(requests)
    with RealClockDriver(service, DriverConfig(completion_log=2)) as driver:
        futures = [driver.submit(p) for p in requests]
        done = [f.result(timeout=WAIT_S) for f in futures]
    assert len(done) == 4                       # every answer delivered
    assert len(driver.completions) == 2         # log keeps only the newest


def test_close_is_idempotent_and_fences_submit():
    service = AllocService(CFG)
    driver = RealClockDriver(service)
    driver.close(timeout=WAIT_S)
    driver.close(timeout=WAIT_S)                        # second close: no-op
    with pytest.raises(DriverClosed):
        driver.submit(sample_params(jax.random.PRNGKey(0), N=4, K=8))


# ---------------------------------------------------------------------------
# backpressure: bounded admission queue
# ---------------------------------------------------------------------------


def test_backpressure_rejects_when_full():
    """With the solver thread deliberately not running, the bounded queue
    must raise AdmissionQueueFull instead of growing without bound."""
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    service = AllocService(CFG)
    driver = RealClockDriver(
        service, DriverConfig(queue_capacity=2, block=False), start=False
    )
    driver.submit(p)
    driver.submit(p)
    with pytest.raises(AdmissionQueueFull):
        driver.submit(p)
    # the queued-but-unsolved requests are still served by the inline drain
    driver.close()
    assert len(driver.completions) == 2


def test_backpressure_block_times_out():
    p = sample_params(jax.random.PRNGKey(0), N=4, K=8)
    service = AllocService(CFG)
    driver = RealClockDriver(
        service,
        DriverConfig(queue_capacity=1, block=True, submit_timeout_s=0.05),
        start=False,
    )
    driver.submit(p)
    with pytest.raises(AdmissionQueueFull):
        driver.submit(p)                                # blocks 0.05s, then raises
    driver.close()
    assert len(driver.completions) == 1


def test_backpressure_blocking_submit_resumes():
    """A blocking submit parked on a full queue must complete once the
    solver thread starts consuming (no timing asserts — just progress)."""
    requests = _stream(3)
    service = AllocService(CFG)
    service.warmup(requests)
    driver = RealClockDriver(
        service, DriverConfig(queue_capacity=1, block=True), start=False
    )
    futures = [driver.submit(requests[0])]
    unblocked = threading.Event()

    def second():
        futures.append(driver.submit(requests[1]))      # parks on the bound
        unblocked.set()

    t = threading.Thread(target=second)
    t.start()
    assert not unblocked.wait(timeout=0.1)              # genuinely blocked
    driver.start()                                      # consumer unblocks it
    assert unblocked.wait(timeout=WAIT_S)
    t.join(timeout=WAIT_S)
    driver.close(timeout=WAIT_S)
    assert len(driver.completions) == 2


# ---------------------------------------------------------------------------
# learned ladder
# ---------------------------------------------------------------------------


def test_learn_buckets_zero_waste_within_budget():
    """One bucket per distinct shape fits the budget -> exact fit, and never
    worse than DEFAULT_BUCKETS on the same mix."""
    mix = {(4, 12): 50, (4, 16): 30, (8, 16): 20}
    ladder = learn_buckets(mix, max_buckets=4)
    assert padded_area_waste(mix, ladder) == 0.0
    assert set(ladder) == {ShapeBucket(4, 12), ShapeBucket(4, 16), ShapeBucket(8, 16)}
    assert padded_area_waste(mix, ladder) <= padded_area_waste(mix, DEFAULT_BUCKETS)


def test_learn_buckets_respects_budget_and_covers():
    mix = {(2, 4): 10, (3, 9): 5, (4, 16): 2, (6, 24): 1, (8, 32): 1}
    ladder = learn_buckets(mix, max_buckets=2)
    assert len(ladder) <= 2
    # every observed shape still fits some bucket (waste computable == covered)
    w2 = padded_area_waste(mix, ladder)
    assert np.isfinite(w2)
    # a bigger budget can only help (greedy is monotone in the budget)
    w4 = padded_area_waste(mix, learn_buckets(mix, max_buckets=4))
    assert w4 <= w2


def test_learn_buckets_weighs_counts():
    """The hot shape gets an exact bucket before the cold one does."""
    hot, cold = (4, 12), (7, 29)
    ladder = learn_buckets({hot: 1000, cold: 1}, max_buckets=2)
    assert ShapeBucket(*hot) in ladder
    assert ShapeBucket(max(4, 7), max(12, 29)) in ladder   # the cover bucket


def test_learn_buckets_validates():
    with pytest.raises(ValueError, match="at least one"):
        learn_buckets({})
    with pytest.raises(ValueError, match="K >= N"):
        learn_buckets({(8, 4): 1})
    with pytest.raises(ValueError, match="max_buckets"):
        learn_buckets({(4, 8): 1}, max_buckets=0)
    with pytest.raises(ValueError, match="must_fit"):
        # transposed must_fit would otherwise seed an invalid K < N bucket
        learn_buckets({(2, 4): 5}, must_fit=[(8, 4)])


def test_ladder_learner_refit_and_fallback():
    learner = LadderLearner(min_samples=5)
    learner.observe(4, 12, count=3)
    snap = learner.refit()
    assert snap.buckets == DEFAULT_BUCKETS                 # below min_samples
    learner.observe(8, 16, count=4)
    snap = learner.refit()
    assert snap.n_observed == 7
    assert snap.waste <= snap.baseline_waste
    assert ShapeBucket(4, 12) in snap.buckets


def test_ladder_learner_uncoverable_fallback_scores_inf():
    """A mix the fallback ladder cannot even serve must score it inf, not
    crash refit — out-of-ladder mixes are exactly what the learner is for."""
    learner = LadderLearner(min_samples=1)
    learner.observe(100, 400)          # beyond DEFAULT_BUCKETS' (64, 256)
    snap = learner.refit()
    assert snap.baseline_waste == float("inf")
    assert snap.waste == 0.0
    assert ShapeBucket(100, 400) in snap.buckets


def test_driver_refit_swaps_ladder_mid_stream():
    """refit() between epochs: new admissions pad into the learned ladder,
    already-served answers are unaffected, and serving keeps working."""
    requests = _stream(4)
    service = AllocService(CFG)
    service.warmup(requests)
    learner = LadderLearner(min_samples=1)
    with RealClockDriver(service, ladder=learner) as driver:
        first = [driver.submit(p) for p in requests[:2]]
        [f.result(timeout=WAIT_S) for f in first]
        snap = driver.refit()
        assert snap.buckets != DEFAULT_BUCKETS
        assert service.cfg.buckets == snap.buckets
        second = [driver.submit(p) for p in requests[2:]]
        done = [f.result(timeout=WAIT_S) for f in second]
    for f, p in zip(done, requests[2:]):
        assert f.alloc.P.shape == (p.N, p.K)
    # epoch-2 requests were padded by the learned ladder: their bucket is one
    # of its shapes (the observed mix is (3,8)/(4,8) -> (4,8) is learnable)
    assert all(c.bucket in {(b.N, b.K) for b in snap.buckets} for c in done)


def test_driver_refit_never_shrinks_coverage():
    """A mid-stream refit that has only observed part of the mix must keep
    every previously-admissible shape admissible: the learned ladder retains
    the current ladder's cover shape. Without that, a (4, 8) submitter racing
    a refit that had only seen (3, 8) died at prepare with "no bucket fits"
    (the deterministic replay of the threaded-stress interleave)."""
    small = sample_request_stream(jax.random.PRNGKey(11), 2, sizes=((3, 8),))
    big = sample_request_stream(jax.random.PRNGKey(12), 1, sizes=((4, 8),))
    service = AllocService(CFG)
    service.warmup(small + big)
    with RealClockDriver(service, ladder=LadderLearner(min_samples=1)) as driver:
        [f.result(timeout=WAIT_S) for f in (driver.submit(p) for p in small)]
        snap = driver.refit()           # learner has ONLY seen (3, 8)
        cover = (
            max(b.N for b in DEFAULT_BUCKETS),
            max(b.K for b in DEFAULT_BUCKETS),
        )
        assert any(b.fits(*cover) for b in snap.buckets)
        c = driver.submit(big[0]).result(timeout=WAIT_S)   # used to ValueError
    assert c.alloc.P.shape == (4, 8)


def test_driver_refit_requires_learner():
    service = AllocService(CFG)
    with RealClockDriver(service) as driver:
        with pytest.raises(RuntimeError, match="LadderLearner"):
            driver.refit()


def test_driver_auto_refit_on_shape_mix_drift():
    """PR-5 leftover closed: with `refit_waste_threshold` set, the solver
    thread itself notices the observed mix's padded waste under the current
    ladder and refits — no caller hook. The seed-7 smoke mix (six (4,8), two
    (3,8)) wastes ~6.7% under DEFAULT_BUCKETS' (4,8) bucket, so a 5%
    threshold trips and the refit ladder (which includes a (3,8) bucket)
    drops it to zero; answers stay correct because padding is
    answer-transparent."""
    requests = _stream(8)
    service = AllocService(CFG)
    service.warmup(requests)
    driver = RealClockDriver(
        service,
        cfg=DriverConfig(
            refit_waste_threshold=0.05, refit_check_every=4, refit_min_samples=4
        ),
        ladder=LadderLearner(min_samples=1),
    )
    with driver:
        done = [f.result(timeout=WAIT_S) for f in (driver.submit(p) for p in requests)]
    assert driver.auto_refits >= 1
    assert driver.summary()["auto_refits"] == driver.auto_refits
    # the swapped ladder serves the observed mix with zero waste...
    assert service.cfg.buckets != DEFAULT_BUCKETS
    assert padded_area_waste(
        [(p.N, p.K) for p in requests], service.cfg.buckets
    ) == 0.0
    # ...and every answer is still the request's own exact-shape allocation
    for c, p in zip(sorted(done, key=lambda c: c.req_id), requests):
        assert c.alloc.P.shape == (p.N, p.K)


def test_driver_auto_refit_disabled_by_default():
    """No threshold (the default) => the driver never refits on its own,
    even with a learner attached — existing callers keep manual control."""
    requests = _stream(6)
    service = AllocService(CFG)
    service.warmup(requests)
    with RealClockDriver(service, ladder=LadderLearner(min_samples=1)) as driver:
        [f.result(timeout=WAIT_S) for f in (driver.submit(p) for p in requests)]
    assert driver.auto_refits == 0
    assert service.cfg.buckets == DEFAULT_BUCKETS
