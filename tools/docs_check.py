"""Docs sanity: README/ARCHITECTURE links resolve, README commands really run.

Two checks, run by CI's docs step (`python tools/docs_check.py`):

1. **Links** — every relative markdown link in `README.md` and
   `docs/ARCHITECTURE.md` must point at an existing file/anchorable doc.
2. **Commands** — every line in README's ```sh fenced blocks must be
   *exercised*: either this script executes it directly (cheap commands on
   the RUN_HERE list), or the command must appear verbatim (modulo extra
   flags) in `.github/workflows/ci.yml`, i.e. another CI step runs it. A
   README command that is neither runnable here nor present in CI fails the
   build — quickstart instructions cannot rot silently.

Illustrative snippets that should NOT be executed (long-running sweeps,
accelerator-only commands) belong in ```text fences, which this script
ignores.
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = [ROOT / "README.md", ROOT / "docs" / "ARCHITECTURE.md"]
CI_YML = ROOT / ".github" / "workflows" / "ci.yml"

#: command prefixes this script executes itself (fast: < ~1 min on CI)
RUN_HERE = (
    "PYTHONPATH=src python examples/quickstart.py",
)

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```sh\s*$(.*?)^```\s*$", re.M | re.S)


def check_links(md: pathlib.Path) -> list[str]:
    errors = []
    for target in _LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = (md.parent / target.split("#", 1)[0]).resolve()
        if not path.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def _normalize(text: str) -> str:
    return " ".join(text.split())


def check_commands(readme: pathlib.Path) -> list[str]:
    ci = _normalize(CI_YML.read_text())
    errors = []
    for block in _FENCE.findall(readme.read_text()):
        for line in block.splitlines():
            cmd = line.strip()
            if not cmd or cmd.startswith("#"):
                continue
            if cmd.startswith(RUN_HERE):
                print(f"[docs-check] running: {cmd}", flush=True)
                proc = subprocess.run(
                    cmd, shell=True, cwd=ROOT, capture_output=True, text=True
                )
                if proc.returncode != 0:
                    errors.append(
                        f"README command failed ({proc.returncode}): {cmd}\n"
                        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
                    )
            elif _normalize(cmd) not in ci:
                errors.append(
                    "README ```sh command neither on the docs-check RUN_HERE "
                    f"list nor present in ci.yml (so nothing runs it): {cmd}"
                )
    return errors


def main() -> int:
    errors = []
    for md in DOCS:
        if not md.exists():
            errors.append(f"missing doc: {md.relative_to(ROOT)}")
            continue
        errors.extend(check_links(md))
    errors.extend(check_commands(DOCS[0]))
    for e in errors:
        print(f"[docs-check] FAIL: {e}", file=sys.stderr)
    if not errors:
        print("[docs-check] OK: links resolve, README commands exercised")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
