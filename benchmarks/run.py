"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark (us_per_call =
wall time of the benchmark's run; derived = pass/fail summary of the
paper-claim checks), then a detailed check listing on stderr.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4]
"""
from __future__ import annotations

import argparse
import sys
import time


def _modules():
    from . import (alg_analysis, bench_allocator, bench_serve, fig3_weights,
                   fig4_pmax, fig5_users_subcarriers, fig6_workloads,
                   fig8_accuracy, table2_exhaustive, roofline_report)

    return {
        "bench_allocator": bench_allocator,
        "bench_serve": bench_serve,
        "fig3_weights": fig3_weights,
        "fig4_pmax": fig4_pmax,
        "fig5_users_subcarriers": fig5_users_subcarriers,
        "fig6_workloads": fig6_workloads,
        "fig8_accuracy": fig8_accuracy,
        "table2_exhaustive": table2_exhaustive,
        "alg_analysis": alg_analysis,
        "roofline_report": roofline_report,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full sweep grids")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    mods = _modules()
    if args.only:
        mods = {k: v for k, v in mods.items() if args.only in k}

    all_checks = {}
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        t0 = time.time()
        try:
            out = mod.run(quick=not args.full)
            # bench modules return (rows, checks) or (rows, checks,
            # perf_checks); perf checks are informational (timing ratios on a
            # shared box) and never count as claim failures
            rows, checks = out[0], out[1]
            perf = out[2] if len(out) > 2 else {}
            dt_us = (time.time() - t0) * 1e6
            n_pass = sum(1 for v in checks.values() if v is True)
            n_check = sum(1 for v in checks.values() if isinstance(v, bool))
            print(f"{name},{dt_us:.0f},checks={n_pass}/{n_check}")
            all_checks[name] = dict(checks)
            all_checks[name].update({f"perf[{k}]": f"INFO:{v}" for k, v in perf.items()})
        except Exception as e:  # noqa: BLE001
            print(f"{name},-1,ERROR:{type(e).__name__}")
            all_checks[name] = {"exception": str(e)}

    print("\n--- paper-claim checks ---", file=sys.stderr)
    failures = 0
    for name, checks in all_checks.items():
        for k, v in checks.items():
            status = v if not isinstance(v, bool) else ("PASS" if v else "FAIL")
            if v is False:
                failures += 1
            print(f"{name}.{k}: {status}", file=sys.stderr)
    if failures:
        print(f"\n{failures} claim-check failure(s)", file=sys.stderr)


if __name__ == "__main__":
    main()
