"""Paper Table II: proposed vs approximate exhaustive search on a toy
(N=4, K=5) instance. Claims: exhaustive finds a (somewhat) better objective;
proposed is orders of magnitude faster.

Grid reductions vs the paper (documented per DESIGN.md §8): per-device total
power levels (spread equally over the device's subcarriers) instead of
per-(n,k) powers; X enumerated exactly (4^5 = 1024 assignments).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import run_baselines, run_proposed, weights, write_csv
from repro.core import sample_params
from repro.core.exhaustive import solve_exhaustive


def run(quick: bool = True, seed: int = 0):
    w = weights()
    params = sample_params(jax.random.PRNGKey(seed), N=4, K=5)

    prop = run_proposed(params, w)
    prop_pgd = run_proposed(params, w, inner="pgd")
    eq = run_baselines(params, w, jax.random.PRNGKey(seed))["equal"]

    t0 = time.time()
    levels = 3 if quick else 4
    ex = solve_exhaustive(
        params, w,
        f_levels=np.linspace(0.25e9, 2e9, levels + 1),
        p_levels_dbm=np.linspace(4, 20, levels),
        rho_levels=np.linspace(0.2, 1.0, 5),
    )
    ex_time = time.time() - t0

    rows = [
        {"method": "equal", "objective": eq["objective"], "runtime_s": 0.0},
        {"method": "proposed(sca)", "objective": prop["objective"],
         "runtime_s": prop["runtime_s"]},
        {"method": "proposed(pgd)", "objective": prop_pgd["objective"],
         "runtime_s": prop_pgd["runtime_s"]},
        {"method": "approx_exhaustive", "objective": float(ex.value),
         "runtime_s": ex_time, "n_evaluated": ex.n_evaluated},
    ]
    write_csv("table2_exhaustive", rows)

    best_prop = min(prop["objective"], prop_pgd["objective"])
    # Runtime claim, honestly: on the TOY instance our vectorised grid search
    # is fast, so the paper's 54x does not reproduce literally. The real
    # content of the claim is scaling — exhaustive cost is
    # Lf^N * Lp^N * Lr * N^K while Alg. A2 is polynomial. Project the
    # default scenario (N=10, K=50) on the measured per-eval throughput.
    evals_per_s = ex.n_evaluated / max(ex_time, 1e-9)
    projected_evals = (4.0**10) * (3.0**10) * 5 * (10.0**50)
    projected_years = projected_evals / evals_per_s / 3.15e7
    rows.append({
        "method": "exhaustive@N=10,K=50 (projected)",
        "objective": float("nan"), "runtime_s": projected_years * 3.15e7,
    })
    checks = {
        "exhaustive_not_much_better": float(ex.value) >= best_prop - 0.35 * abs(best_prop),
        "proposed_beats_equal": best_prop < eq["objective"],
        "exhaustive_intractable_at_scale": projected_years > 1e6,
    }
    return rows, checks
