"""Shared benchmark helpers: timing, CSV output, allocator wrappers."""
from __future__ import annotations

import csv
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core import AllocatorConfig, Weights, sample_params, solve
from repro.core import baselines as B
from repro.core.system import report

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def weights(k1=1.0, k2=1.0, k3=1.0) -> Weights:
    return Weights(jnp.float32(k1), jnp.float32(k2), jnp.float32(k3))


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") else out
    return out, time.time() - t0


def run_proposed(params, w, inner="sca"):
    solver = jax.jit(lambda p: solve(p, w, AllocatorConfig(inner=inner)).alloc)
    solver(params)                       # warm-up: trace + compile
    alloc, dt = timed(lambda: jax.block_until_ready(solver(params)))
    rep = {k: float(v) for k, v in report(params, w, alloc).items()}
    rep["runtime_s"] = dt
    return rep


def run_baselines(params, w, key):
    out = {}
    for name, alloc in [
        ("equal", B.equal_allocation(params)),
        ("comm_only", B.comm_opt_only(params, w, key)),
        ("comp_only", B.comp_opt_only(params, w)),
        ("random", B.random_allocation(params, key)),
    ]:
        out[name] = {k: float(v) for k, v in report(params, w, alloc).items()}
    return out


def write_csv(name: str, rows: list[dict]):
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{name}.csv"
    if not rows:
        return path
    keys = sorted({k for r in rows for k in r})
    with open(path, "w", newline="") as f:
        wtr = csv.DictWriter(f, fieldnames=keys)
        wtr.writeheader()
        wtr.writerows(rows)
    return path
