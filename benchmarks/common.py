"""Shared benchmark helpers: timing, CSV output, allocator wrappers."""
from __future__ import annotations

import csv
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core import (
    AllocatorConfig, SystemParams, Weights, solve, solve_batch,
    stack_params, stack_weights, tree_index,
)
from repro.core import baselines as B
from repro.core.system import feasible, report
from repro.scenarios import get_family

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def weights(k1=1.0, k2=1.0, k3=1.0) -> Weights:
    return Weights(jnp.float32(k1), jnp.float32(k2), jnp.float32(k3))


def sample_scenario(key, *, scenario: str = "iid_rayleigh", **kwargs) -> SystemParams:
    """One scenario draw from a registered family — every fig script's
    single-draw entry point, so ``--scenario`` reaches all of them."""
    return get_family(scenario).sample(key, **kwargs)


def sample_sweep(
    key, overrides: list[dict], *, scenario: str = "iid_rayleigh", **base_kwargs
) -> list[SystemParams]:
    """One draw per sweep point, all from the SAME key and family: only the
    per-point ``overrides`` (e.g. ``{"p_max_dbm": 24.0}``) move between
    points, so a sweep isolates the swept knob from channel randomness.

    This replaces the per-figure copies of the same list-comprehension
    (fig4's p_max sweep, fig6's workload sweep, ...); same-shape results
    stack straight into `run_proposed_batch`.
    """
    fam = get_family(scenario)
    return [fam.sample(key, **{**base_kwargs, **o}) for o in overrides]


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") else out
    return out, time.time() - t0


def run_proposed(params, w, inner="sca"):
    solver = jax.jit(lambda p: solve(p, w, AllocatorConfig(inner=inner)).alloc)
    solver(params)                       # warm-up: trace + compile
    alloc, dt = timed(lambda: jax.block_until_ready(solver(params)))
    rep = {k: float(v) for k, v in report(params, w, alloc).items()}
    rep["feasible"] = bool(feasible(params, alloc))
    rep["runtime_s"] = dt
    return rep


def run_proposed_batch(scenarios, w, inner="sca"):
    """Solve same-shape scenarios in ONE batched call.

    ``scenarios`` is either an already batch-stacked ``SystemParams`` (from
    `sample_params_batch`) or a list of per-scenario ones. Returns a
    per-scenario list of report dicts; ``runtime_s`` is the batched
    wall-clock amortised over the batch (the whole sweep is a single compiled
    program, so per-scenario cost is not separable).
    """
    pb = scenarios if isinstance(scenarios, SystemParams) else stack_params(scenarios)
    n = pb.g.shape[0]
    cfg = AllocatorConfig(inner=inner)
    jax.block_until_ready(solve_batch(pb, w, cfg))      # warm-up: trace+compile
    res, dt = timed(lambda: jax.block_until_ready(solve_batch(pb, w, cfg)))
    reports = []
    for i in range(n):
        p_i, a_i = tree_index(pb, i), tree_index(res.alloc, i)
        rep = {k: float(v) for k, v in report(p_i, w, a_i).items()}
        rep["feasible"] = bool(feasible(p_i, a_i))
        rep["runtime_s"] = dt / n
        reports.append(rep)
    return reports


def run_proposed_weights_batch(params, weights_list, inner="sca"):
    """Solve ONE scenario under many weight settings in ONE batched call.

    Replicates ``params`` over the leading axis and stacks the per-point
    `Weights` with a matching batch axis (`solve_batch(weights_batched=True)`)
    so a whole weight sweep (paper Fig. 3) is a single jitted program instead
    of per-point solves. Returns per-point report dicts; ``runtime_s`` is the
    batched wall-clock amortised over the sweep.
    """
    weights_list = list(weights_list)
    n = len(weights_list)
    pb = stack_params([params] * n)
    wb = stack_weights(weights_list)
    cfg = AllocatorConfig(inner=inner)
    jax.block_until_ready(
        solve_batch(pb, wb, cfg, weights_batched=True)
    )  # warm-up: trace+compile
    res, dt = timed(
        lambda: jax.block_until_ready(solve_batch(pb, wb, cfg, weights_batched=True))
    )
    reports = []
    for i in range(n):
        a_i = tree_index(res.alloc, i)
        rep = {k: float(v) for k, v in report(params, weights_list[i], a_i).items()}
        rep["feasible"] = bool(feasible(params, a_i))
        rep["runtime_s"] = dt / n
        reports.append(rep)
    return reports


def run_baselines(params, w, key):
    out = {}
    for name, alloc in [
        ("equal", B.equal_allocation(params)),
        ("comm_only", B.comm_opt_only(params, w, key)),
        ("comp_only", B.comp_opt_only(params, w)),
        ("random", B.random_allocation(params, key)),
    ]:
        rep = {k: float(v) for k, v in report(params, w, alloc).items()}
        # baselines can violate P1's constraints (comm_only blows the SemCom
        # deadline at low p_max — its rho = 1 objective is not attainable);
        # record it so claim checks compare like against like
        rep["feasible"] = bool(feasible(params, alloc))
        out[name] = rep
    return out


def write_csv(name: str, rows: list[dict]):
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{name}.csv"
    if not rows:
        return path
    keys = sorted({k for r in rows for k in r})
    with open(path, "w", newline="") as f:
        wtr = csv.DictWriter(f, fieldnames=keys)
        wtr.writeheader()
        wtr.writerows(rows)
    return path
