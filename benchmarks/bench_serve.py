"""Serving load benchmark: micro-batched `AllocService` vs solve-per-request.

Sweeps Poisson arrival rate x bucket policy over a mixed-size scenario
stream:

  * ``service``     — shape-bucket ladder, micro-batching to ``max_batch=8``
    slots, one AOT-compiled `solve_batch` executable per bucket;
  * ``per_request`` — the baseline: exact shapes, batch of 1, i.e. a jitted
    `solve` per request (what the seed's callers did).

Arrivals run on a virtual clock, solves charge measured wall time (see
`repro.serve.loadgen`), so throughput and p50/p95 latency are honest while
the sweep stays laptop-sized. Writes ``BENCH_serve.json`` at the repo root
(full run) so future PRs have a serving-perf trajectory; ``--smoke`` writes
``experiments/bench/BENCH_serve_smoke.json`` with a tiny allocator config for
CI.

  PYTHONPATH=src python -m benchmarks.bench_serve            # full, root JSON
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI-sized
"""
from __future__ import annotations

import json
import pathlib
import platform

import jax

from repro.core import AllocatorConfig, DEFAULT_BUCKETS, sample_request_stream
from repro.core.pgd import PGDConfig
from repro.serve import AllocService, BatchPolicy, ServeConfig, poisson_arrivals, run_load

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_JSON = ROOT / "BENCH_serve.json"
# smoke/quick runs use a reduced allocator config — methodologically different
# numbers must not clobber the committed full-run trajectory file
OUT_JSON_SMOKE = ROOT / "experiments" / "bench" / "BENCH_serve_smoke.json"

MAX_BATCH = 8
# heterogeneous but ladder-aligned: (4,12) pads into the (4,16) bucket (1.33x
# area waste), the others hit their bucket exactly. Bucket-misaligned sizes
# shift the trade toward the per-request baseline (padding waste eats the
# batching win) — that regime is what the ladder's geometry exists to bound.
SIZES = ((4, 12), (4, 16), (8, 16))


def _policies(allocator: AllocatorConfig, max_wait_s: float):
    policies = {
        "service": ServeConfig(
            policy=BatchPolicy(max_batch=MAX_BATCH, max_wait_s=max_wait_s),
            buckets=DEFAULT_BUCKETS,
            allocator=allocator,
        ),
        "per_request": ServeConfig(
            policy=BatchPolicy(max_batch=1, max_wait_s=0.0),
            buckets=None,
            allocator=allocator,
        ),
    }
    if jax.device_count() > 1:
        # scenario-sharded flushes: per-device batch of MAX_BATCH, bucket slots
        # device_count x MAX_BATCH (skipped on one device, where it would just
        # duplicate "service"); run under
        # XLA_FLAGS=--xla_force_host_platform_device_count=N to sweep on CPU
        policies["service_sharded"] = ServeConfig(
            policy=BatchPolicy(max_batch=MAX_BATCH, max_wait_s=max_wait_s),
            buckets=DEFAULT_BUCKETS,
            allocator=allocator,
            shard_batch=True,
        )
    return policies


def run(quick: bool = False, seed: int = 0, smoke: bool | None = None):
    smoke = quick if smoke is None else smoke
    # the interesting regime is arrival rate >= 1/t_single: the per-request
    # baseline saturates while the service's batches fill, so the sweep's top
    # rate must overdrive the baseline's capacity
    if smoke:
        allocator = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
        n_requests, rates, max_wait_s = 48, (400.0,), 0.02
    else:
        allocator = AllocatorConfig(inner="pgd")
        n_requests, rates, max_wait_s = 64, (5.0, 20.0, 100.0, 400.0), 0.05

    key = jax.random.PRNGKey(seed)
    requests = sample_request_stream(key, n_requests, sizes=SIZES)

    rows = []
    for policy_name, cfg in _policies(allocator, max_wait_s).items():
        warm = AllocService(cfg)
        warm.warmup(requests)          # compile once, outside the timed runs
        for rate in rates:
            # fresh metrics per rate, shared compiled cache
            service = AllocService(cfg, executables=warm.executables)
            arrivals = poisson_arrivals(jax.random.fold_in(key, 1), n_requests, rate)
            result = run_load(service, requests, arrivals)
            rows.append(
                {
                    "policy": policy_name,
                    "rate_rps": rate,
                    "max_batch": cfg.policy.max_batch,
                    "shard_batch": cfg.shard_batch,
                    "throughput_rps": result.throughput_rps,
                    "makespan_s": result.makespan_s,
                    "busy_s": result.busy_s,
                    **result.summary,
                }
            )

    def best(policy):
        return max(
            (r for r in rows if r["policy"] == policy), key=lambda r: r["throughput_rps"]
        )

    svc, base = best("service"), best("per_request")
    checks = {
        "service_beats_per_request_throughput": svc["throughput_rps"]
        > base["throughput_rps"],
        "service_batches_fill_under_load": svc["mean_batch_size"] >= 2.0,
        "all_requests_answered": all(
            r["completed"] == r["requests"] for r in rows
        ),
        "tail_latency_recorded": all(
            r["latency_p95_s"] >= r["latency_p50_s"] > 0 for r in rows
        ),
    }

    result = {
        "sizes": [list(s) for s in SIZES],
        "n_requests": n_requests,
        "max_batch": MAX_BATCH,
        "inner": allocator.inner,
        "smoke": smoke,
        "rows": rows,
        "speedup_throughput": svc["throughput_rps"] / max(base["throughput_rps"], 1e-12),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
    }
    out = OUT_JSON_SMOKE if smoke else OUT_JSON
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2) + "\n")
    return rows, checks


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows, checks = run(smoke=args.smoke, seed=args.seed)
    for r in rows:
        print(
            f"{r['policy']:>12} rate={r['rate_rps']:>6.1f}/s "
            f"thpt={r['throughput_rps']:7.2f}/s p50={r['latency_p50_s']*1e3:7.1f}ms "
            f"p95={r['latency_p95_s']*1e3:7.1f}ms occ={r['batch_occupancy_mean']:.2f}"
        )
    print("checks:", checks)
    # nonzero exit on a failed claim check so the CI smoke step gates serving
    # performance, not just crashes
    sys.exit(0 if all(v is not False for v in checks.values()) else 1)
