"""Serving load benchmark: micro-batched `AllocService` vs solve-per-request.

Three comparisons over a mixed-size scenario stream:

1. **Policy sweep** (virtual clock): Poisson arrival rate x bucket policy —
   ``service`` (shape-bucket ladder, micro-batching to ``max_batch=8``) vs
   ``per_request`` (exact shapes, batch of 1, the seed's baseline), plus a
   sharded flavour when more than one device is visible.
2. **Learned ladder** (virtual clock): the same service with a
   `repro.serve.ladder` bucket ladder fit to the stream's (N, K) mix —
   padded-area waste vs `DEFAULT_BUCKETS` is computed exactly from the shape
   histogram, and a throughput row runs at the top arrival rate.
3. **Async overlap** (REAL clock): the threaded `RealClockDriver` vs a
   single-threaded synchronous loop over the same paced arrival schedule —
   the async win is admission/padding overlapping device solves. The
   driver's answers are also replayed through the virtual-clock loadgen and
   must match hardened-X-exactly (the equivalence gate).
4. **Warm-start cache** (virtual clock): the same service with
   `repro.serve.warmstart` enabled vs cold, on the time-correlated
   ``gauss_markov`` trace (the recurring-user workload the cache targets).
   Gated deterministically: per-request objective dominance (warm <= cold,
   float32 tolerance), exact-X replay equivalence re-injecting the recorded
   warm starts, and cache-hit accounting (hits + misses == lookups, one put
   per completion). Hit rate, solve-iteration savings and p95 latency are
   reported informationally.

Virtual-clock runs charge solves at measured wall time (see
`repro.serve.loadgen`), so throughput and p50/p95 latency are honest while
the sweep stays laptop-sized. Writes ``BENCH_serve.json`` at the repo root
(full run) so future PRs have a serving-perf trajectory; ``--smoke`` writes
``experiments/bench/BENCH_serve_smoke.json`` with a tiny allocator config for
CI.

Exit status gates ONLY the deterministic claims (every request answered,
driver==loadgen equivalence, learned-ladder waste <= default): timing-ratio
checks are recorded as informational ``perf_checks`` — a loaded CI box must
not fail an unrelated PR (the bench_allocator convention).

  PYTHONPATH=src python -m benchmarks.bench_serve            # full, root JSON
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI-sized
"""
from __future__ import annotations

import json
import pathlib
import platform
import time
from collections import Counter

import jax

from repro.core import AllocatorConfig, DEFAULT_BUCKETS
from repro.core.pgd import PGDConfig
from repro.serve import (
    scenario_stream,
    AllocService,
    BatchPolicy,
    RealClockDriver,
    ServeConfig,
    WarmStartConfig,
    learn_buckets,
    pace_stream,
    padded_area_waste,
    poisson_arrivals,
    run_load,
    same_hardened_assignments,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_JSON = ROOT / "BENCH_serve.json"
# smoke/quick runs use a reduced allocator config — methodologically different
# numbers must not clobber the committed full-run trajectory file
OUT_JSON_SMOKE = ROOT / "experiments" / "bench" / "BENCH_serve_smoke.json"

MAX_BATCH = 8
# heterogeneous but ladder-aligned: (4,12) pads into the (4,16) bucket (1.33x
# area waste), the others hit their bucket exactly. Bucket-misaligned sizes
# shift the trade toward the per-request baseline (padding waste eats the
# batching win) — that regime is what the learned ladder exists to close.
SIZES = ((4, 12), (4, 16), (8, 16))


def _policies(allocator: AllocatorConfig, max_wait_s: float):
    policies = {
        "service": ServeConfig(
            policy=BatchPolicy(max_batch=MAX_BATCH, max_wait_s=max_wait_s),
            buckets=DEFAULT_BUCKETS,
            allocator=allocator,
        ),
        "per_request": ServeConfig(
            policy=BatchPolicy(max_batch=1, max_wait_s=0.0),
            buckets=None,
            allocator=allocator,
        ),
    }
    if jax.device_count() > 1:
        # scenario-sharded flushes: per-device batch of MAX_BATCH, bucket slots
        # device_count x MAX_BATCH (skipped on one device, where it would just
        # duplicate "service"); run under
        # XLA_FLAGS=--xla_force_host_platform_device_count=N to sweep on CPU
        policies["service_sharded"] = ServeConfig(
            policy=BatchPolicy(max_batch=MAX_BATCH, max_wait_s=max_wait_s),
            buckets=DEFAULT_BUCKETS,
            allocator=allocator,
            shard_batch=True,
        )
    return policies


def _row(policy_name, rate, cfg, completed, makespan_s, busy_s, summary):
    return {
        "policy": policy_name,
        "rate_rps": rate,
        "max_batch": cfg.policy.max_batch,
        "shard_batch": cfg.shard_batch,
        "throughput_rps": completed / max(makespan_s, 1e-12),
        "makespan_s": makespan_s,
        "busy_s": busy_s,
        **summary,
    }


def _run_virtual(policy_name, cfg, requests, arrivals, rate, executables, rows):
    service = AllocService(cfg, executables=executables)
    result = run_load(service, requests, arrivals)
    rows.append(
        _row(
            policy_name, rate, cfg,
            len(result.completions), result.makespan_s, result.busy_s,
            result.summary,
        )
    )
    return result


def _drive_async(cfg, requests, schedule, executables):
    """Paced real-clock stream through the threaded driver (solves overlap
    admission: the solver thread runs while this thread pads and paces)."""
    service = AllocService(cfg, executables=executables)
    driver = RealClockDriver(service)
    futures, t0 = pace_stream(driver, requests, schedule)
    driver.close(timeout=600.0)
    makespan = driver.now() - t0
    busy = service.metrics.solves_s.total     # exact even past the cap
    # read answers off the futures (authoritative for every request), not the
    # bounded completion log — the equivalence gate must not depend on
    # DriverConfig.completion_log vs n_real
    done = [f.result(timeout=0.0) for f in futures]
    return done, makespan, busy, service.metrics.summary()


def _drive_sync(cfg, requests, schedule, executables):
    """The no-overlap baseline: one thread paces arrivals AND solves, so a
    running solve blocks admission (arrivals queue behind it in real time).
    Deadline flushes still fire on time while idle — the only difference from
    the async driver is the missing admission/solve overlap."""
    service = AllocService(cfg, executables=executables)
    completions = []
    t0 = time.monotonic()
    now = lambda: time.monotonic() - t0
    i, n = 0, len(requests)
    while i < n or service.pending() > 0:
        deadline = service.next_deadline()
        t_next = schedule[i] if i < n else None
        wake = min(t for t in (deadline, t_next) if t is not None) if (
            deadline is not None or t_next is not None
        ) else None
        if wake is not None and wake > now():
            time.sleep(wake - now())
        while i < n and schedule[i] <= now():
            # stamp the TRUE arrival time (like the loadgen): a request that
            # queued behind a solve must be charged that wait, and its
            # max-wait deadline runs from when it arrived, not when the
            # blocked loop got around to admitting it
            service.submit(requests[i], now=schedule[i])
            i += 1
        done, _ = service.flush_due(now=now())
        completions.extend(done)
    makespan = now()
    busy = service.metrics.solves_s.total
    return completions, makespan, busy, service.metrics.summary()


def run(quick: bool = False, seed: int = 0, smoke: bool | None = None):
    smoke = quick if smoke is None else smoke
    # the interesting regime is arrival rate >= 1/t_single: the per-request
    # baseline saturates while the service's batches fill, so the sweep's top
    # rate must overdrive the baseline's capacity
    if smoke:
        allocator = AllocatorConfig(inner="pgd", outer_iters=2, pgd=PGDConfig(steps=60))
        n_requests, rates, max_wait_s = 48, (400.0,), 0.02
        n_real, real_rate = 16, 100.0
    else:
        allocator = AllocatorConfig(inner="pgd")
        n_requests, rates, max_wait_s = 64, (5.0, 20.0, 100.0, 400.0), 0.05
        n_real, real_rate = 32, 50.0

    key = jax.random.PRNGKey(seed)
    requests = scenario_stream(key, n_requests, sizes=SIZES)

    rows = []
    policy_cfgs = _policies(allocator, max_wait_s)
    service_execs = None
    for policy_name, cfg in policy_cfgs.items():
        warm = AllocService(cfg)
        warm.warmup(requests)          # compile once, outside the timed runs
        if policy_name == "service":
            service_execs = warm.executables   # reused by the sections below
        for rate in rates:
            # fresh metrics per rate, shared compiled cache
            arrivals = poisson_arrivals(jax.random.fold_in(key, 1), n_requests, rate)
            _run_virtual(
                policy_name, cfg, requests, arrivals, rate, warm.executables, rows
            )

    # --- learned bucket ladder vs DEFAULT_BUCKETS (tentpole) ----------------
    mix = Counter((p.N, p.K) for p in requests)
    learned = learn_buckets(mix, max_buckets=len(DEFAULT_BUCKETS))
    waste = {
        "shape_mix": {f"{n}x{k}": c for (n, k), c in sorted(mix.items())},
        "learned_buckets": [[b.N, b.K] for b in learned],
        "waste_learned": padded_area_waste(mix, learned),
        "waste_default": padded_area_waste(mix, DEFAULT_BUCKETS),
    }
    # share the sweep's executable cache: learned buckets that coincide with
    # DEFAULT_BUCKETS entries cache-hit (keys pin bucket shape + meta +
    # allocator, so differing buckets miss safely), only new shapes compile
    cfg_learned = policy_cfgs["service"]._replace(buckets=learned)
    warm = AllocService(cfg_learned, executables=service_execs)
    warm.warmup(requests)
    top_rate = max(rates)
    arrivals = poisson_arrivals(jax.random.fold_in(key, 1), n_requests, top_rate)
    _run_virtual(
        "service_learned_ladder", cfg_learned, requests, arrivals, top_rate,
        warm.executables, rows,
    )

    # --- time-correlated vs i.i.d. load (scenario registry) -----------------
    # the gauss_markov stream shares SIZES and bbar with the i.i.d. one, so
    # the swept "service" cache serves it with zero new compiles; any
    # throughput delta is the request CONTENT (correlated channel draws),
    # recorded as an informational row family, never exit-gating
    gm_requests = scenario_stream(
        key, n_requests, scenario="gauss_markov", sizes=SIZES
    )
    arrivals = poisson_arrivals(jax.random.fold_in(key, 1), n_requests, top_rate)
    gm_cold = _run_virtual(
        "service_gauss_markov", policy_cfgs["service"], gm_requests, arrivals,
        top_rate, service_execs, rows,
    )

    # --- warm-start cache: warm vs cold on the correlated trace (tentpole) --
    # same stream, same arrivals, same compiled cache — the only difference
    # is `ServeConfig.warmstart`, so any objective/iteration delta is the
    # cache's doing. The dominance invariant (a warm start is one more
    # multi-start candidate, selected only if better) makes warm <= cold a
    # DETERMINISTIC claim per request; hit counts depend on batch boundaries
    # (measured solve times move deadline flushes), so rates stay
    # informational.
    cfg_warm = policy_cfgs["service"]._replace(warmstart=WarmStartConfig())
    warm_svc = AllocService(cfg_warm, executables=service_execs)
    warm_svc.warmup(gm_requests)       # compile the refine programs untimed
    warm_res = run_load(warm_svc, gm_requests, arrivals)
    warm_stats = warm_svc.warm_cache.stats()
    rows.append(
        _row(
            "service_gauss_markov_warm", top_rate, cfg_warm,
            len(warm_res.completions), warm_res.makespan_s, warm_res.busy_s,
            {**warm_res.summary, **warm_stats},
        )
    )
    # replay the warm run with the RECORDED per-request starts injected into
    # a cache-disabled service: answers must match the warm run exactly
    # (equivalence stays well-defined even though cache state is
    # schedule-dependent — the recorded starts ARE the schedule's outcome)
    warm_by_id = {c.req_id: c for c in warm_res.completions}
    recorded_starts = [warm_by_id[i].warm_start for i in range(n_requests)]
    warm_replay = run_load(
        AllocService(policy_cfgs["service"], executables=service_execs),
        gm_requests, arrivals, warm_starts=recorded_starts,
    )
    cold_obj = {c.req_id: c.objective for c in gm_cold.completions}
    warm_obj = {c.req_id: c.objective for c in warm_res.completions}
    # float32 round-off headroom on the eq. 13 scale (objectives are O(1))
    warm_dominates = all(
        warm_obj[rid] <= cold_obj[rid] + 1e-5 * max(1.0, abs(cold_obj[rid]))
        for rid in cold_obj
    )
    n_hits_flagged = sum(c.warm_hit for c in warm_res.completions)
    warm_accounting_ok = (
        # one lookup per admitted request, one put per completion, and the
        # hit counter agrees with the per-completion hit flags
        warm_stats["warm_cache_hits"] + warm_stats["warm_cache_misses"]
        == n_requests
        and warm_stats["warm_cache_puts"] == n_requests
        and warm_stats["warm_cache_hits"] == n_hits_flagged
    )

    # --- async real-clock driver vs synchronous loop (tentpole) -------------
    # same config as the swept "service" policy, so its warm cache covers
    # every bucket here — no recompiles inside the real-clock sections
    cfg_srv = policy_cfgs["service"]
    schedule = [
        float(t)
        for t in poisson_arrivals(jax.random.fold_in(key, 2), n_real, real_rate)
    ]
    drv_done, mk, busy, summ = _drive_async(
        cfg_srv, requests[:n_real], schedule, service_execs
    )
    rows.append(_row("driver_real_async", real_rate, cfg_srv, len(drv_done), mk, busy, summ))
    sync_done, mk, busy, summ = _drive_sync(
        cfg_srv, requests[:n_real], schedule, service_execs
    )
    rows.append(_row("driver_real_sync", real_rate, cfg_srv, len(sync_done), mk, busy, summ))
    # equivalence gate: the real-clock driver must answer exactly like the
    # virtual-clock loadgen on the same stream (same hardened X per request)
    replay = _run_virtual(
        "driver_virtual_replay", cfg_srv, requests[:n_real], schedule, real_rate,
        service_execs, rows,
    )
    driver_equivalent = same_hardened_assignments(drv_done, replay.completions)

    def best(policy):
        return max(
            (r for r in rows if r["policy"] == policy), key=lambda r: r["throughput_rps"]
        )

    svc, base = best("service"), best("per_request")
    # deterministic claims — these gate the exit status
    checks = {
        "all_requests_answered": all(
            r["completed"] == r["requests"] for r in rows
        ),
        "tail_latency_recorded": all(
            r["latency_p95_s"] >= r["latency_p50_s"] > 0 for r in rows
        ),
        "learned_ladder_waste_le_default": waste["waste_learned"]
        <= waste["waste_default"] + 1e-12,
        "driver_equivalent_to_virtual_loadgen": driver_equivalent,
        "driver_drained_everything": len(drv_done) == n_real and len(sync_done) == n_real,
        # warm-start deterministic claims (dominance invariant + replay +
        # accounting — see the warm section above)
        "warm_dominates_cold_objective": warm_dominates,
        "warm_replay_equivalent": same_hardened_assignments(
            warm_res.completions, warm_replay.completions
        ),
        "warm_cache_accounting": warm_accounting_ok,
    }
    # timing-dependent observations — recorded, printed, NEVER gating (a busy
    # 2-core CI box must not fail an unrelated PR on a throughput ratio)
    perf_checks = {
        "service_beats_per_request_throughput": svc["throughput_rps"]
        > base["throughput_rps"],
        "service_batches_fill_under_load": svc["mean_batch_size"] >= 2.0,
        "async_overlap_not_slower": best("driver_real_async")["throughput_rps"]
        >= 0.9 * best("driver_real_sync")["throughput_rps"],
        # scenario-registry row family: i.i.d. vs time-correlated load at the
        # same rate/sizes — correlated draws should serve comparably (the
        # solver cost is shape-, not content-, dominated)
        "correlated_load_comparable_to_iid": best("service_gauss_markov")[
            "throughput_rps"
        ]
        >= 0.5 * svc["throughput_rps"],
        # warm-start informational rows: hit pattern depends on batch
        # boundaries (measured solve times), so these observe, never gate
        "warm_cache_hits_on_correlated_trace": warm_stats["warm_cache_hits"] > 0,
        "warm_converges_no_slower_than_cold": (
            warm_res.summary["warm_iters_mean"]
            <= warm_res.summary["cold_iters_mean"]
            if warm_stats["warm_cache_hits"] > 0
            else True
        ),
        "warm_p95_comparable_to_cold": (
            warm_res.summary["latency_p95_s"]
            <= 2.0 * gm_cold.summary["latency_p95_s"]
        ),
    }

    result = {
        "sizes": [list(s) for s in SIZES],
        "n_requests": n_requests,
        "max_batch": MAX_BATCH,
        "inner": allocator.inner,
        "smoke": smoke,
        "rows": rows,
        "ladder": waste,
        "warmstart": {
            **warm_stats,
            "warm_iters_mean": warm_res.summary["warm_iters_mean"],
            "cold_iters_mean": warm_res.summary["cold_iters_mean"],
            "iter_savings_mean": (
                warm_res.summary["cold_iters_mean"]
                - warm_res.summary["warm_iters_mean"]
            ),
            "p95_warm_s": warm_res.summary["latency_p95_s"],
            "p95_cold_s": gm_cold.summary["latency_p95_s"],
        },
        "real_driver": {"n_requests": n_real, "rate_rps": real_rate},
        "speedup_throughput": svc["throughput_rps"] / max(base["throughput_rps"], 1e-12),
        "checks": checks,
        "perf_checks": perf_checks,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
    }
    out = OUT_JSON_SMOKE if smoke else OUT_JSON
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2) + "\n")
    return rows, checks, perf_checks


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows, checks, perf_checks = run(smoke=args.smoke, seed=args.seed)
    for r in rows:
        print(
            f"{r['policy']:>22} rate={r['rate_rps']:>6.1f}/s "
            f"thpt={r['throughput_rps']:7.2f}/s p50={r['latency_p50_s']*1e3:7.1f}ms "
            f"p95={r['latency_p95_s']*1e3:7.1f}ms occ={r['batch_occupancy_mean']:.2f}"
        )
    print("checks (gating):", checks)
    print("perf checks (informational):", perf_checks)
    # nonzero exit only on a failed DETERMINISTIC claim (equivalence /
    # completeness / ladder waste) — timing ratios stay informational
    sys.exit(0 if all(v is not False for v in checks.values()) else 1)
