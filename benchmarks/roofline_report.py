"""Aggregate the dry-run JSONs into the §Roofline table (markdown + CSV)."""
from __future__ import annotations

import json
import pathlib

from .common import OUT, write_csv

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str | None = None, baseline_only: bool = True):
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        if baseline_only and len(p.stem.split("__")) != 3:
            continue  # skip --tag'd hillclimb variant records
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run(quick: bool = True, mesh: str = "16x16"):
    recs = load_records(mesh)
    rows = []
    for r in recs:
        row = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": r["status"],
        }
        if r["status"] == "ok":
            rl = r["roofline"]
            row.update({
                "kind": r["kind"],
                "compute_s": rl["compute_s"],
                "memory_s": rl["memory_s"],
                "collective_s": rl["collective_s"],
                "dominant": rl["dominant"],
                "hbm_gib": r["hbm_gib"],
                "fits_hbm": r["fits_hbm"],
                "useful_flops_ratio": r.get("useful_flops_ratio"),
                "fsdp": r.get("fsdp"),
            })
        else:
            row["note"] = r.get("reason") or (r.get("error") or "")[:80]
        rows.append(row)
    write_csv(f"roofline_{mesh.replace('x','_')}", rows)
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    err = [r for r in rows if r["status"] == "error"]
    checks = {
        "all_pairs_lower_or_skip": len(err) == 0,
        "n_ok": len(ok), "n_skipped": len(skipped), "n_error": len(err),
    }
    return rows, checks


def markdown_table(mesh: str = "16x16") -> str:
    rows, _ = run(mesh=mesh)
    hdr = ("| arch | shape | kind | compute_s | memory_s | collective_s | "
           "dominant | HBM GiB | fits | useful-FLOPs |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                f"{r['status']}: {r.get('note','')} | — | — | — |"
            )
            continue
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['dominant'].replace('_s','')} "
            f"| {r['hbm_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} "
            f"| {ratio:.2f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r['kind']} | - | - | - | - | - | - | - |"
        )
    return "\n".join(lines)
