"""Regenerate the data-driven sections of EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import csv
import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"
BENCH = ROOT / "experiments" / "bench"


def _fmt(x, nd=2):
    return f"{x:.{nd}e}" if isinstance(x, float) else str(x)


def dryrun_summary() -> str:
    lines = []
    for mesh in ("16x16", "pod2x16x16"):
        recs = []
        for p in sorted(DRYRUN.glob("*.json")):
            if len(p.stem.split("__")) != 3:
                continue
            r = json.loads(p.read_text())
            if r.get("mesh") == mesh:
                recs.append(r)
        ok = sum(r["status"] == "ok" for r in recs)
        sk = sum(r["status"] == "skipped" for r in recs)
        er = sum(r["status"] == "error" for r in recs)
        chips = 512 if "pod" in mesh else 256
        lines.append(
            f"* **{mesh}** ({chips} chips): {ok} pairs lower+compile OK, "
            f"{sk} skipped by design, {er} errors — out of {len(recs)} recorded."
        )
    return "\n".join(lines)


def roofline_table(mesh="16x16") -> str:
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        if len(p.stem.split("__")) != 3:
            continue
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh:
            continue
        rows.append(r)
    hdr = ("| arch | shape | kind | compute_s | memory_s* | collective_s | "
           "dominant | HBM GiB | fits | useful-FLOPs |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"{r['status']}: {r.get('reason', '')[:60]} |"
            )
            continue
        rl = r["roofline"]
        u = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | {rl['dominant'].replace('_s', '')} "
            f"| {r['hbm_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} "
            f"| {u:.2f} |"
        )
    return "\n".join(out)


def paper_results() -> str:
    out = []
    t2 = BENCH / "table2_exhaustive.csv"
    if t2.exists():
        out.append("### Table II analogue (toy N=4, K=5)\n")
        out.append("| method | objective | runtime_s |")
        out.append("|---|---|---|")
        with open(t2) as f:
            for row in csv.DictReader(f):
                out.append(
                    f"| {row['method']} | {float(row['objective']):.3f} "
                    f"| {float(row['runtime_s']):.2f} |"
                )
        out.append("")
    f4 = BENCH / "fig4_pmax.csv"
    if f4.exists():
        out.append("### Fig. 4 analogue (objective/energy by method x P_max)\n")
        out.append("| P_max dBm | method | objective | energy J | T_FL s |")
        out.append("|---|---|---|---|---|")
        with open(f4) as f:
            for row in csv.DictReader(f):
                out.append(
                    f"| {row['pmax_dbm']} | {row['method']} "
                    f"| {float(row['objective']):.3f} "
                    f"| {float(row['energy_total']):.3f} "
                    f"| {float(row['t_fl']):.3f} |"
                )
        out.append("")
    out.append(
        "Full CSVs for figs 3/5/6/8 live in `experiments/bench/`; the\n"
        "pass/fail claim checks are printed by `python -m benchmarks.run`."
    )
    return "\n".join(out)


def pod_comparison() -> str:
    """Single-pod vs multi-pod per-device HBM + dominant terms (train/prefill)."""
    by_key = {}
    for p in sorted(DRYRUN.glob("*.json")):
        if len(p.stem.split("__")) != 3:
            continue
        r = json.loads(p.read_text())
        if r["status"] != "ok":
            continue
        by_key.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    out = [
        "| arch | shape | HBM GiB 256c | HBM GiB 512c | coll_s 256c | coll_s 512c |",
        "|---|---|---|---|---|---|",
    ]
    for (arch, shape), recs in sorted(by_key.items()):
        if "16x16" not in recs or "pod2x16x16" not in recs:
            continue
        a, b = recs["16x16"], recs["pod2x16x16"]
        if a["kind"] not in ("train", "prefill"):
            continue
        out.append(
            f"| {arch} | {shape} | {a['hbm_gib']:.1f} | {b['hbm_gib']:.1f} "
            f"| {a['roofline']['collective_s']:.2e} "
            f"| {b['roofline']['collective_s']:.2e} |"
        )
    out.append(
        "\nDoubling to 512 chips roughly halves per-device activations/optimizer"
        " state (batch splits over the pod axis) at the cost of pod-axis"
        " gradient all-reduce — the dry-run quantifies both sides."
    )
    return "\n".join(out)


def patch(md: str, marker: str, content: str) -> str:
    """Replace the region between <!-- X --> and <!-- /X -->."""
    start, end = f"<!-- {marker} -->", f"<!-- /{marker} -->"
    assert start in md and end in md, marker
    pre = md.split(start)[0]
    post = md.split(end)[1]
    return pre + start + "\n\n" + content + "\n" + end + post


def main():
    path = ROOT / "EXPERIMENTS.md"
    md = path.read_text()
    md = patch(md, "PAPER_RESULTS", paper_results() + "\n")
    md = patch(md, "DRYRUN_SUMMARY", dryrun_summary() + "\n")
    md = patch(md, "ROOFLINE_TABLE", roofline_table() + "\n")
    md = patch(md, "POD_COMPARISON", pod_comparison() + "\n")
    path.write_text(md)
    print("EXPERIMENTS.md sections regenerated")


if __name__ == "__main__":
    main()
