"""Paper Fig. 3: energy/time/accuracy vs the weight parameters k1, k2, k3.

Claims validated (paper §V-A):
  (a) k1 up  -> total energy down, FL time up;
  (b) k2 up  -> FL time down, energy up;
  (c) k3 up  -> SemCom tx energy up (rho up), FL comp/tx energy ~flat.
"""
from __future__ import annotations

import jax

from .common import run_proposed_weights_batch, sample_scenario, weights, write_csv

SWEEP = (0.25, 1.0, 4.0, 16.0)


def run(quick: bool = True, seed: int = 0, scenario: str = "iid_rayleigh"):
    params = sample_scenario(jax.random.PRNGKey(seed), scenario=scenario)
    sweep = SWEEP[1:3] if quick else SWEEP
    # the whole 3 x len(sweep) grid is ONE jitted solve_batch call with a
    # batched Weights axis (weights_batched=True) — one compile, wide kernels
    points = []
    for which in ("kappa1", "kappa2", "kappa3"):
        for val in sweep:
            kw = {"k1": 1.0, "k2": 1.0, "k3": 1.0}
            kw["k" + which[-1]] = val
            points.append((which, val, weights(**kw)))
    reports = run_proposed_weights_batch(params, [w for _, _, w in points])
    rows = [
        {"sweep": which, "value": val, **rep}
        for (which, val, _), rep in zip(points, reports)
    ]
    write_csv("fig3_weights", rows)

    checks = {}
    def series(which, field):
        return [r[field] for r in rows if r["sweep"] == which]

    checks["k1_energy_down"] = series("kappa1", "energy_total")[-1] <= series("kappa1", "energy_total")[0] * 1.15
    checks["k2_time_down"] = series("kappa2", "t_fl")[-1] <= series("kappa2", "t_fl")[0] * 1.15
    checks["k3_rho_up"] = series("kappa3", "rho")[-1] >= series("kappa3", "rho")[0] - 1e-6
    checks["k3_semcom_up"] = series("kappa3", "energy_semcom")[-1] >= series("kappa3", "energy_semcom")[0] * 0.85
    return rows, checks
