"""Paper Fig. 4: energy & time vs maximum transmit power, proposed vs the four
baselines. Claim: proposed has the lowest total energy at every P_max."""
from __future__ import annotations

import jax

from .common import run_baselines, run_proposed, weights, write_csv
from repro.core import sample_params

PMAX_DBM = (12.0, 16.0, 20.0, 24.0)


def run(quick: bool = True, seed: int = 0):
    w = weights()
    rows = []
    sweep = PMAX_DBM[1::2] if quick else PMAX_DBM
    for pmax in sweep:
        params = sample_params(jax.random.PRNGKey(seed), p_max_dbm=pmax)
        rep = run_proposed(params, w)
        rows.append({"pmax_dbm": pmax, "method": "proposed", **rep})
        rep_pgd = run_proposed(params, w, inner="pgd")
        rows.append({"pmax_dbm": pmax, "method": "proposed_pgd", **rep_pgd})
        for name, r in run_baselines(params, w, jax.random.PRNGKey(seed + 1)).items():
            rows.append({"pmax_dbm": pmax, "method": name, **r})
    write_csv("fig4_pmax", rows)

    checks = {}
    for pmax in sweep:
        sub = {r["method"]: r for r in rows if r["pmax_dbm"] == pmax}
        best = min(v["objective"] for k, v in sub.items() if k not in ("proposed", "proposed_pgd"))
        checks[f"beats_baselines@{pmax}dBm"] = (
            min(sub["proposed"]["objective"], sub["proposed_pgd"]["objective"])
            <= best + 1e-3
        )
        checks[f"lowest_energy@{pmax}dBm"] = (
            min(sub["proposed"]["energy_total"], sub["proposed_pgd"]["energy_total"])
            <= min(v["energy_total"] for k, v in sub.items() if "proposed" not in k) * 1.05
        )
    return rows, checks
