"""Paper Fig. 4: energy & time vs maximum transmit power, proposed vs the four
baselines. Claim: proposed has the lowest total energy at every P_max.

The P_max sweep shares (N, K), so all sweep points are stacked with
`stack_params` and solved in ONE batched `solve_batch` call per method
variant instead of a Python loop of per-point solves.
"""
from __future__ import annotations

import jax

from .common import run_baselines, run_proposed_batch, sample_sweep, weights, write_csv

PMAX_DBM = (12.0, 16.0, 20.0, 24.0)


def run(quick: bool = True, seed: int = 0, scenario: str = "iid_rayleigh"):
    w = weights()
    rows = []
    sweep = PMAX_DBM[1::2] if quick else PMAX_DBM
    # same key for every point: identical channels, only the power budget moves
    params_list = sample_sweep(
        jax.random.PRNGKey(seed),
        [{"p_max_dbm": pmax} for pmax in sweep],
        scenario=scenario,
    )
    reps_sca = run_proposed_batch(params_list, w, inner="sca")
    reps_pgd = run_proposed_batch(params_list, w, inner="pgd")
    for pmax, params, rep, rep_pgd in zip(sweep, params_list, reps_sca, reps_pgd):
        rows.append({"pmax_dbm": pmax, "method": "proposed", **rep})
        rows.append({"pmax_dbm": pmax, "method": "proposed_pgd", **rep_pgd})
        for name, r in run_baselines(params, w, jax.random.PRNGKey(seed + 1)).items():
            rows.append({"pmax_dbm": pmax, "method": name, **r})
    write_csv("fig4_pmax", rows)

    checks = {}
    for pmax in sweep:
        sub = {r["method"]: r for r in rows if r["pmax_dbm"] == pmax}
        # compare objectives against FEASIBLE points only: comm_only keeps
        # rho = 1 but violates the SemCom deadline (13f) at low p_max, so its
        # objective is not an attainable point of P1 — and the proposed side
        # must itself be feasible to claim the win
        feas_base = [
            v["objective"] for k, v in sub.items()
            if k not in ("proposed", "proposed_pgd") and v["feasible"]
        ]
        feas_prop = [
            sub[k]["objective"] for k in ("proposed", "proposed_pgd")
            if sub[k]["feasible"]
        ]
        if not feas_prop:
            checks[f"beats_baselines@{pmax}dBm"] = False  # proposed infeasible
        elif not feas_base:
            checks[f"beats_baselines@{pmax}dBm"] = "skipped (no feasible baseline)"
        else:
            checks[f"beats_baselines@{pmax}dBm"] = (
                min(feas_prop) <= min(feas_base) + 1e-3
            )
        checks[f"lowest_energy@{pmax}dBm"] = (
            min(sub["proposed"]["energy_total"], sub["proposed_pgd"]["energy_total"])
            <= min(v["energy_total"] for k, v in sub.items() if "proposed" not in k) * 1.05
        )
    return rows, checks
