"""Closed-loop FedSem benchmark: concurrent FL jobs over the live allocation
service (the `repro.launch.fedsem_e2e` harness, recorded as BENCH rows).

Phases (shared compiled-executable cache, see the harness docstring):
backend equivalence (PlannedBackend == virtual-clock ServiceBackend, exact
hardened X), the A(rho) feedback loop (a refit from the job's own
proxy-accuracy measurements must be applied and stay monotone), J concurrent
heterogeneous FL jobs sharing one `RealClockDriver` — each a TENANT whose
refits are scoped to its own rounds — then the non-interference gate: each
job re-run alone must reproduce its co-tenanted trajectory exactly. Rows
record every job's fig8-style per-round accuracy/energy trajectory (tenant-
tagged; these rounds co-batched across tenants), each job's own refit
trajectory, plus the service-side latency/occupancy summary under FL load.

Writes ``BENCH_fedsem.json`` at the repo root (full run) so future PRs have
a closed-loop trajectory; ``--smoke`` writes
``experiments/bench/BENCH_fedsem_smoke.json`` with a tiny autoencoder and a
reduced allocator for CI.

Exit status gates ONLY the deterministic claims (equivalence, refit
monotonicity, tenant non-interference, every job finishing every round):
throughput/occupancy
observations are informational ``perf_checks`` — a loaded CI box must not
fail an unrelated PR (the bench_serve convention).

  PYTHONPATH=src python -m benchmarks.bench_fedsem            # full, root JSON
  PYTHONPATH=src python -m benchmarks.bench_fedsem --smoke    # CI-sized
"""
from __future__ import annotations

import json
import pathlib
import platform

import jax

from repro.core import tree_bits
from repro.launch.fedsem_e2e import (
    check_backend_equivalence,
    check_noninterference,
    harness_config,
    make_job,
    run_multijob,
    run_refit_loop,
    tenant_id,
    trajectory,
)
from repro.semcom import init_params

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_JSON = ROOT / "BENCH_fedsem.json"
# smoke runs use a reduced allocator + tiny AE — methodologically different
# numbers must not clobber the committed full-run trajectory file
OUT_JSON_SMOKE = ROOT / "experiments" / "bench" / "BENCH_fedsem_smoke.json"


def run(quick: bool = False, seed: int = 0, smoke: bool | None = None):
    smoke = quick if smoke is None else smoke
    allocator, serve_cfg, specs, rounds, ae, batch, eval_batch = harness_config(
        smoke, rounds=None, jobs=None
    )
    key = jax.random.PRNGKey(seed)
    executables: dict = {}

    probe = make_job(specs[0], rounds, ae, batch, eval_batch)
    d_bits = tree_bits(init_params(jax.random.PRNGKey(0), probe.ae))
    eq = check_backend_equivalence(
        jax.random.fold_in(key, 100), probe.cfg.fl, allocator, serve_cfg,
        d_bits, executables,
    )
    _, refit = run_refit_loop(
        jax.random.fold_in(key, 200),
        make_job(specs[0], rounds, ae, batch, eval_batch),
        serve_cfg, executables,
    )
    key3 = jax.random.fold_in(key, 300)
    jobs = [make_job(s, rounds, ae, batch, eval_batch) for s in specs]
    results, summary = run_multijob(key3, jobs, serve_cfg, executables)
    # per-tenant non-interference: each job re-run alone (same seed fold and
    # tenant id) must reproduce its co-tenanted trajectory exactly
    nonint = check_noninterference(key3, jobs, results, serve_cfg, executables)

    # one row per (job, round): the multi-job accuracy/energy trajectory,
    # tagged with the job's tenant id (these rounds co-batched across tenants)
    rows = []
    for i, (spec, job, res) in enumerate(zip(specs, jobs, results)):
        traj = trajectory(res)
        for rnd in range(traj["rounds"]):
            rows.append(
                {
                    "job": res.name,
                    "tenant": tenant_id(job, i),
                    "scenario": spec[1],
                    "n_clients": spec[2],
                    "n_subcarriers": spec[3],
                    "round": rnd,
                    "loss": traj["loss"][rnd],
                    "rho": traj["rho"][rnd],
                    "energy": traj["energy"][rnd],
                    "t_fl": traj["t_fl"][rnd],
                    "objective": traj["objective"][rnd],
                }
            )
    # each job's own refit trajectory: the fit its LATER rounds solved under,
    # scoped to its tenant registry entry (never visible to co-tenants)
    refits = [
        {
            "job": res.name,
            "tenant": tenant_id(job, i),
            "refit_applied": res.refit_applied,
            "refit_round": res.refit_round,
            "fit_a": float(res.accuracy_fit.a) if res.accuracy_fit else None,
            "fit_b": float(res.accuracy_fit.b) if res.accuracy_fit else None,
            "n_measurements": len(res.measurements),
        }
        for i, (job, res) in enumerate(zip(jobs, results))
    ]
    # plus the service-side view of the same load: latency + the occupancy of
    # the MIXED-TENANT co-batches (distinct tenants' rounds sharing one solve)
    service_row = {
        "jobs": len(results),
        "tenants": len({r["tenant"] for r in rows}),
        "rounds": rounds,
        "requests": summary.get("completed"),
        "latency_p50_s": summary.get("latency_p50_s"),
        "latency_p95_s": summary.get("latency_p95_s"),
        "batch_occupancy_mean": summary.get("batch_occupancy_mean"),
        "mean_batch_size": summary.get("mean_batch_size"),
        "cache_hit_rate": summary.get("cache_hit_rate"),
    }

    completed = all(len(r.history) == rounds for r in results)
    checks = {
        "service_backend_matches_planned": eq["equivalent"],
        "refit_applied_and_monotone": refit["ok"],
        "tenant_noninterference_as_if_alone": nonint["ok"],
        "all_jobs_completed_all_rounds": completed,
        "every_round_allocated": all(0.0 < r["rho"] <= 1.0 for r in rows),
        "service_latency_recorded": bool(
            summary.get("latency_p95_s", 0) >= summary.get("latency_p50_s", 0) > 0
        ),
    }
    perf_checks = {
        # co-batching across concurrent jobs is timing-dependent (jobs drift
        # apart as their training speeds differ) — observed, never gating
        "concurrent_rounds_co_batched": summary.get("mean_batch_size", 0) > 1.0,
        "training_reduced_loss_somewhere": any(
            r.history[-1].loss < r.history[0].loss for r in results
        ),
    }

    result = {
        "specs": [list(s) for s in specs],
        "rounds": rounds,
        "inner": allocator.inner,
        "smoke": smoke,
        "equivalence": eq,
        "refit": refit,
        "noninterference": nonint,
        "rows": rows,
        "refits": refits,
        "service": service_row,
        "checks": checks,
        "perf_checks": perf_checks,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
    }
    out = OUT_JSON_SMOKE if smoke else OUT_JSON
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2) + "\n")
    return rows, checks, perf_checks


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows, checks, perf_checks = run(smoke=args.smoke, seed=args.seed)
    for r in rows:
        print(
            f"{r['job']:>8} [{r['scenario']:>14}] round {r['round']} "
            f"loss={r['loss']:.4f} rho={r['rho']:.3f} "
            f"E={r['energy']:.3f}J t={r['t_fl']:.3f}s"
        )
    print("checks (gating):", checks)
    print("perf checks (informational):", perf_checks)
    sys.exit(0 if all(v is not False for v in checks.values()) else 1)
