"""Paper §IV-D analyses: Alg. A2 convergence (IV-D.2) and runtime scaling
with N and K (IV-D.1: O((2N + (4NK+3N+K) I_max) J_max) — i.e. ~linear in
N*K for fixed iteration counts).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import timed, weights, write_csv
from repro.core import AllocatorConfig, sample_params, solve


def run(quick: bool = True, seed: int = 0):
    w = weights()
    rows = []

    # --- convergence traces over several channels (paper Fig-less claim) ---
    converged = 0
    n_seeds = 3 if quick else 8
    for s in range(n_seeds):
        params = sample_params(jax.random.PRNGKey(seed + s))
        res = solve(params, w, AllocatorConfig(inner="sca"))
        tr = np.asarray(res.trace, np.float64)
        total = abs(tr[-1] - tr[0]) + 1e-9
        tail = abs(tr[-1] - tr[-2])
        converged += int(tail <= 0.35 * total + 0.15)
        rows.append({
            "kind": "trace", "seed": s,
            **{f"s{i}": float(v) for i, v in enumerate(tr)},
        })

    # --- runtime scaling in N*K (warm jit, one compile per shape) ---
    sizes = [(4, 12), (8, 24)] if quick else [(4, 12), (8, 24), (12, 48), (16, 64)]
    times = []
    for n, k in sizes:
        params = sample_params(jax.random.PRNGKey(seed), N=n, K=k)
        solver = jax.jit(lambda p: solve(p, w, AllocatorConfig(inner="pgd")).alloc.rho)
        solver(params)  # warm
        _, dt = timed(lambda: jax.block_until_ready(solver(params)))
        times.append(dt)
        rows.append({"kind": "runtime", "N": n, "K": k, "NK": n * k, "runtime_s": dt})
    write_csv("alg_analysis", rows)

    # runtime should grow clearly sub-quadratically in N*K (theory: ~linear)
    nk = [n * k for n, k in sizes]
    growth = (times[-1] / max(times[0], 1e-9)) / (nk[-1] / nk[0]) ** 2
    checks = {
        "all_traces_converge": converged == n_seeds,
        "runtime_subquadratic_in_NK": growth < 1.0,
    }
    return rows, checks
