"""Batched vs sequential allocation: the perf case for `solve_batch`.

Solves B i.i.d. scenarios four ways:

  * ``sequential_eager`` — a Python loop of plain `solve` calls, the seed's
    `fl/federated.py` pattern (per-op dispatch every round);
  * ``sequential_jit``   — a jitted single-scenario `solve`, compiled once,
    called B times (one device program per scenario);
  * ``batched``          — ONE jitted `solve_batch` call over the stacked
    scenarios (one device program for the whole sweep, single device);
  * ``sharded``          — the same program with the scenario axis split over
    a `scenario_mesh` of all local devices (B/device_count per device).

The sharded-vs-single-device comparison is only meaningful with >1 device;
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to measure
it on CPU (virtual devices share the physical cores, so CPU numbers bound
overhead rather than demonstrate speedup — the sweep exists so accelerator
runs land in the same JSON).

Writes ``BENCH_allocator.json`` at the repo root so future PRs have a perf
trajectory to compare against. Run as ``python -m benchmarks.bench_allocator``.
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

import jax
import numpy as np

from repro.core import (
    AllocatorConfig,
    Weights,
    sample_params_batch,
    scenario_mesh,
    solve,
    solve_batch,
    tree_index,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_JSON = ROOT / "BENCH_allocator.json"
# quick runs extrapolate the eager baseline — methodologically different
# numbers must not clobber the committed full-run trajectory file
OUT_JSON_QUICK = ROOT / "experiments" / "bench" / "BENCH_allocator_quick.json"


def _bench(fn, warmup: int = 1, reps: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run(quick: bool = False, seed: int = 0, batch: int = 16, n: int = 4, k: int = 12):
    w = Weights.ones()
    cfg = AllocatorConfig(inner="pgd")
    pb = sample_params_batch(jax.random.PRNGKey(seed), batch, N=n, K=k)
    scenarios = [tree_index(pb, i) for i in range(batch)]

    t_batched = _bench(lambda: solve_batch(pb, w, cfg).alloc.rho)

    # sharded sweep: same program, scenario axis split over all local devices
    mesh = scenario_mesh()
    t_sharded = _bench(lambda: solve_batch(pb, w, cfg, mesh=mesh).alloc.rho)
    x_single = np.asarray(solve_batch(pb, w, cfg).alloc.X)
    x_sharded = np.asarray(solve_batch(pb, w, cfg, mesh=mesh).alloc.X)

    solve_jit = jax.jit(lambda p: solve(p, w, cfg))
    t_seq_jit = _bench(
        lambda: [solve_jit(p).alloc.rho for p in scenarios]
    )

    # eager loop: warm once so jax's eager fragment caches are hot — this is
    # still generous to the baseline relative to the seed's cold-start rounds
    n_eager = 2 if quick else batch
    solve(scenarios[0], w, cfg)
    t0 = time.perf_counter()
    for p in scenarios[:n_eager]:
        jax.block_until_ready(solve(p, w, cfg).alloc.rho)
    t_seq_eager = (time.perf_counter() - t0) / n_eager * batch

    result = {
        "batch": batch,
        "N": n,
        "K": k,
        "inner": cfg.inner,
        "batched_s": t_batched,
        "sharded_s": t_sharded,
        "sharded_devices": mesh.size,
        "sequential_jit_s": t_seq_jit,
        "sequential_eager_s": t_seq_eager,
        "sequential_eager_extrapolated": n_eager != batch,
        "speedup_vs_eager_loop": t_seq_eager / t_batched,
        "speedup_vs_jit_loop": t_seq_jit / t_batched,
        "speedup_sharded_vs_single_device": t_batched / t_sharded,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
    }
    out = OUT_JSON_QUICK if quick else OUT_JSON
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2) + "\n")

    checks = {
        "batched_3x_faster_than_solve_loop": result["speedup_vs_eager_loop"] >= 3.0,
        "batched_not_slower_than_jit_loop": result["speedup_vs_jit_loop"] >= 1.0,
        # correctness claim, not a perf one: the device split must be invisible
        # (CPU virtual devices share cores, so no speedup is promised there)
        "sharded_matches_single_device": bool((x_sharded == x_single).all()),
    }
    return [result], checks


if __name__ == "__main__":
    rows, checks = run()
    print(json.dumps(rows[0], indent=2))
    print("checks:", checks)
