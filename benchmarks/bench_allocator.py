"""Batched vs sequential allocation: the perf case for `solve_batch`.

Solves B i.i.d. scenarios four ways:

  * ``sequential_eager`` — a Python loop of plain `solve` calls, the seed's
    `fl/federated.py` pattern (per-op dispatch every round);
  * ``sequential_jit``   — a jitted single-scenario `solve`, compiled once,
    called B times (one device program per scenario);
  * ``batched``          — ONE jitted `solve_batch` call over the stacked
    scenarios (one device program for the whole sweep, single device);
  * ``sharded``          — the same program with the scenario axis split over
    a `scenario_mesh` of all local devices (B/device_count per device).

The sharded-vs-single-device comparison is only meaningful with >1 device;
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to measure
it on CPU (virtual devices share the physical cores, so CPU numbers bound
overhead rather than demonstrate speedup — the sweep exists so accelerator
runs land in the same JSON).

Also sweeps the batched objective-scoring path (`kernels/fedsem_objective`,
PR 4): `solve_batch` with the kernel objective on vs off (same hardened X
asserted), plus a raw scoring microbenchmark — one fused
`ops.objective_grid_batch` call over (B, G) candidates vs a per-scenario
loop of grid evaluations. On CPU the fused path runs the kernel's jnp
oracle (Pallas dispatches on TPU); a Pallas-interpret parity check rides
along so the JSON also records that the kernel path agrees.

Writes ``BENCH_allocator.json`` at the repo root so future PRs have a perf
trajectory to compare against. Run as ``python -m benchmarks.bench_allocator``
(``--smoke`` for the CI-sized quick run).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AllocatorConfig,
    Weights,
    sample_params,
    sample_params_batch,
    scenario_mesh,
    solve,
    solve_batch,
    tree_index,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_JSON = ROOT / "BENCH_allocator.json"
# quick runs extrapolate the eager baseline — methodologically different
# numbers must not clobber the committed full-run trajectory file
OUT_JSON_QUICK = ROOT / "experiments" / "bench" / "BENCH_allocator_quick.json"


def _bench(fn, warmup: int = 1, reps: int = 1) -> float:
    """Best-of-``reps`` (min is the right location statistic on a small
    shared-core box: scheduler noise only ever adds time)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _objective_sweep(quick: bool, seed: int = 0):
    """Fused batched scoring vs a per-scenario loop, at several (B, G)."""
    from repro.kernels.fedsem_objective import ops, ref

    sizes = [(4, 256)] if quick else [(8, 512), (32, 2048), (64, 8192)]
    n = 8
    rows = []
    for b, g in sizes:
        params = sample_params(jax.random.PRNGKey(seed), N=n, K=2 * n)
        ks = jax.random.split(jax.random.PRNGKey(seed + 1), 4)
        f = jax.random.uniform(ks[0], (b, g, n), minval=1e8, maxval=2e9)
        p = jax.random.uniform(ks[1], (b, g, n), minval=1e-3, maxval=0.1)
        r = jax.random.uniform(ks[2], (b, g, n), minval=1e5, maxval=3e7)
        rho = jax.random.uniform(ks[3], (b, g), minval=0.05, maxval=1.0)
        row = lambda v: jnp.broadcast_to(v[None], (b,) + v.shape)
        vecs = tuple(
            row(v) for v in (params.c, params.d, params.D, params.C,
                             params.t_sc_max, params.f_max)
        )
        kw = dict(
            xi=float(params.xi), eta=float(params.eta),
            dev_mask=row(params.dev_mask),
        )

        fused = jax.jit(
            lambda f, p, r, rho: ops.objective_grid_batch(
                f, p, r, rho, *vecs, 1.0, 1.0, 1.0, **kw
            )
        )
        t_fused = _bench(lambda: fused(f, p, r, rho), warmup=2, reps=3)

        per_scenario = jax.jit(
            lambda f1, p1, r1, rho1: ref.objective_grid(
                f1, p1, r1, rho1,
                params.c, params.d, params.D, params.C,
                params.t_sc_max, params.f_max,
                float(params.xi), float(params.eta), 1.0, 1.0, 1.0,
                dev_mask=params.dev_mask,
            )
        )

        def loop():
            return [per_scenario(f[i], p[i], r[i], rho[i]) for i in range(b)]

        t_loop = _bench(loop, warmup=2, reps=3)

        # Pallas path correctness on a small slice (interpret is an
        # interpreter — timing it would benchmark the interpreter, not TPUs)
        bi, gi = min(b, 2), min(g, 128)
        got = ops.objective_grid_batch(
            f[:bi, :gi], p[:bi, :gi], r[:bi, :gi], rho[:bi, :gi],
            *(v[:bi] for v in vecs), 1.0, 1.0, 1.0,
            xi=kw["xi"], eta=kw["eta"], dev_mask=kw["dev_mask"][:bi],
            use_pallas=True, interpret=True,
        )
        want = ref.objective_grid_batch(
            f[:bi, :gi], p[:bi, :gi], r[:bi, :gi], rho[:bi, :gi],
            *(v[:bi] for v in vecs), 1.0, 1.0, 1.0,
            xi=kw["xi"], eta=kw["eta"], dev_mask=kw["dev_mask"][:bi],
        )
        ok = bool(
            np.allclose(np.asarray(got), np.asarray(want), rtol=5e-7, atol=1e-5)
        )
        rows.append({
            "B": b, "G": g, "N": n,
            "fused_batch_s": t_fused,
            "per_scenario_loop_s": t_loop,
            "speedup_fused_vs_loop": t_loop / t_fused,
            "pallas_interpret_matches_ref": ok,
        })
    return rows


def run(quick: bool = False, seed: int = 0, batch: int = 16, n: int = 4, k: int = 12):
    w = Weights.ones()
    cfg = AllocatorConfig(inner="pgd")                      # kernel objective on
    cfg_jnp = cfg._replace(use_kernel_objective=False)      # plain jnp scoring
    pb = sample_params_batch(jax.random.PRNGKey(seed), batch, N=n, K=k)
    scenarios = [tree_index(pb, i) for i in range(batch)]

    reps = 1 if quick else 3
    t_batched = _bench(lambda: solve_batch(pb, w, cfg).alloc.rho, reps=reps)
    t_batched_jnp = _bench(
        lambda: solve_batch(pb, w, cfg_jnp).alloc.rho, reps=reps
    )

    # sharded sweep: same program, scenario axis split over all local devices
    mesh = scenario_mesh()
    t_sharded = _bench(
        lambda: solve_batch(pb, w, cfg, mesh=mesh).alloc.rho, reps=reps
    )
    x_single = np.asarray(solve_batch(pb, w, cfg).alloc.X)
    x_sharded = np.asarray(solve_batch(pb, w, cfg, mesh=mesh).alloc.X)
    x_jnp_obj = np.asarray(solve_batch(pb, w, cfg_jnp).alloc.X)

    solve_jit = jax.jit(lambda p: solve(p, w, cfg))
    t_seq_jit = _bench(
        lambda: [solve_jit(p).alloc.rho for p in scenarios]
    )

    # eager loop: warm once so jax's eager fragment caches are hot — this is
    # still generous to the baseline relative to the seed's cold-start rounds
    n_eager = 2 if quick else batch
    solve(scenarios[0], w, cfg)
    t0 = time.perf_counter()
    for p in scenarios[:n_eager]:
        jax.block_until_ready(solve(p, w, cfg).alloc.rho)
    t_seq_eager = (time.perf_counter() - t0) / n_eager * batch

    result = {
        "batch": batch,
        "N": n,
        "K": k,
        "inner": cfg.inner,
        "batched_s": t_batched,
        "batched_jnp_objective_s": t_batched_jnp,
        "sharded_s": t_sharded,
        "sharded_devices": mesh.size,
        "sequential_jit_s": t_seq_jit,
        "sequential_eager_s": t_seq_eager,
        "sequential_eager_extrapolated": n_eager != batch,
        "speedup_vs_eager_loop": t_seq_eager / t_batched,
        "speedup_vs_jit_loop": t_seq_jit / t_batched,
        "speedup_sharded_vs_single_device": t_batched / t_sharded,
        "speedup_kernel_vs_jnp_objective": t_batched_jnp / t_batched,
        "objective_sweep": _objective_sweep(quick, seed),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
    }
    out = OUT_JSON_QUICK if quick else OUT_JSON
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2) + "\n")

    checks = {
        "batched_3x_faster_than_solve_loop": result["speedup_vs_eager_loop"] >= 3.0,
        "batched_not_slower_than_jit_loop": result["speedup_vs_jit_loop"] >= 1.0,
        # correctness claims, not perf ones: the device split and the kernel
        # objective path must both be invisible in the hardened assignment
        "sharded_matches_single_device": bool((x_sharded == x_single).all()),
        "kernel_objective_matches_jnp_objective": bool(
            (x_jnp_obj == x_single).all()
        ),
        "pallas_interpret_matches_ref": all(
            r["pallas_interpret_matches_ref"] for r in result["objective_sweep"]
        ),
    }
    return [result], checks


#: checks that gate CI (exit nonzero): equivalence claims only — the perf
#: ratios above are informational on shared runners, where a single noisy
#: smoke-mode timing rep must not fail an unrelated PR
GATING_CHECKS = (
    "sharded_matches_single_device",
    "kernel_objective_matches_jnp_objective",
    "pallas_interpret_matches_ref",
)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized quick run (small batch, extrapolated eager baseline; "
        "writes experiments/bench/BENCH_allocator_quick.json)",
    )
    ap.add_argument("--batch", type=int, default=None)
    args = ap.parse_args(argv)
    batch = args.batch if args.batch is not None else (8 if args.smoke else 16)
    rows, checks = run(quick=args.smoke, batch=batch)
    print(json.dumps(rows[0], indent=2))
    print("checks:", checks)
    failed = {k: checks[k] for k in GATING_CHECKS if not checks[k]}
    if failed:
        raise SystemExit(f"benchmark correctness checks failed: {failed}")


if __name__ == "__main__":
    main()
