"""Paper Fig. 8: (a) kappa3 vs chosen compression rate rho; (b) accuracy vs
rho for two concave fits (YOLOv5 + YOLOv3 stand-in), plus our FL-autoencoder
re-fit when experiments/bench/ae_accuracy.csv exists (examples/fedsem_autoencoder.py).
"""
from __future__ import annotations

import jax
import numpy as np

from .common import OUT, run_proposed_weights_batch, sample_scenario, weights, write_csv
from repro.core.accuracy import default_accuracy, yolov3_accuracy

KAPPA3 = (0.05, 0.2, 1.0, 5.0, 20.0)


def run(quick: bool = True, seed: int = 0, scenario: str = "iid_rayleigh"):
    params = sample_scenario(jax.random.PRNGKey(seed), scenario=scenario)
    rows = []
    sweep = KAPPA3[1:4] if quick else KAPPA3
    # one scenario x all kappa3 points: a single weights-batched solve
    for k3, rep in zip(
        sweep, run_proposed_weights_batch(params, [weights(k3=k3) for k3 in sweep])
    ):
        rows.append({"kappa3": k3, **rep})
    write_csv("fig8a_kappa3_rho", rows)

    acc_rows = []
    for rho in np.linspace(0.05, 1.0, 20):
        acc_rows.append({
            "rho": float(rho),
            "yolov5_fit": float(default_accuracy().value(rho)),
            "yolov3_fit": float(yolov3_accuracy().value(rho)),
        })
    write_csv("fig8b_accuracy_vs_rho", acc_rows)

    rhos = [r["rho"] for r in rows]
    a5 = [r["yolov5_fit"] for r in acc_rows]
    checks = {
        "rho_nondecreasing_in_k3": all(
            rhos[i + 1] >= rhos[i] - 1e-6 for i in range(len(rhos) - 1)
        ),
        "accuracy_concave_increasing": all(
            a5[i + 1] > a5[i] for i in range(len(a5) - 1)
        ),
    }
    return rows + acc_rows, checks
