"""Paper Fig. 6: total energy vs SemCom task workload (C_n multiples).

Claim: heavier semantic payloads -> higher total energy; FL energy ~flat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import run_proposed, run_proposed_batch, sample_scenario, sample_sweep, weights, write_csv

MULTIPLES = (1.0, 2.0, 4.0, 8.0, 16.0)
BASE_C = 1e6  # "light" workload, paper §V-D


def run(quick: bool = True, seed: int = 0, scenario: str = "iid_rayleigh"):
    w = weights()
    rows = []
    sweep = MULTIPLES[::2] if quick else MULTIPLES
    # same key every point — only the payload moves; one batched solve
    params_list = sample_sweep(
        jax.random.PRNGKey(seed),
        [{"C_round_bits": BASE_C * mult, "L_rounds": 10} for mult in sweep],
        scenario=scenario,
    )
    for mult, rep in zip(sweep, run_proposed_batch(params_list, w)):
        rows.append({"workload_multiple": mult, **rep})

    # mixed per-group workloads (Fig 6a): 5 groups of 2 devices
    params = sample_scenario(jax.random.PRNGKey(seed), scenario=scenario)
    group_C = np.repeat([1.0, 2.0, 4.0, 8.0, 16.0], 2) * BASE_C * 10
    import dataclasses

    params = dataclasses.replace(params, C=jnp.asarray(group_C, jnp.float32))
    rep = run_proposed(params, w)
    rows.append({"workload_multiple": -1.0, **rep})  # -1 = mixed groups
    write_csv("fig6_workloads", rows)

    e = [r["energy_semcom"] for r in rows if r["workload_multiple"] > 0]
    checks = {"semcom_energy_up_with_workload": e[-1] >= e[0]}
    return rows, checks
