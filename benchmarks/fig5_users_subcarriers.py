"""Paper Fig. 5: energy & FL time vs (N users x K subcarriers).

Claims: more subcarriers -> energy/time trend down; more users (same K) ->
energy and FL time up.
"""
from __future__ import annotations

import jax

from .common import run_proposed, weights, write_csv
from repro.core import sample_params

USERS = (4, 8, 16)
SUBCARRIERS = (20, 40, 60)


def run(quick: bool = True, seed: int = 0):
    w = weights()
    rows = []
    users = USERS[:2] if quick else USERS
    subs = SUBCARRIERS[:2] if quick else SUBCARRIERS
    for n in users:
        for k in subs:
            params = sample_params(jax.random.PRNGKey(seed), N=n, K=k)
            rep = run_proposed(params, w)
            rows.append({"N": n, "K": k, **rep})
    write_csv("fig5_users_subcarriers", rows)

    checks = {}
    # more users at fixed K => more energy
    k0 = subs[0]
    e_by_n = [r["energy_total"] for r in rows if r["K"] == k0]
    checks["energy_up_with_users"] = e_by_n[-1] >= e_by_n[0] * 0.9
    t_by_n = [r["t_fl"] for r in rows if r["K"] == k0]
    checks["tfl_up_with_users"] = t_by_n[-1] >= t_by_n[0] * 0.9
    # more subcarriers at fixed N => energy not worse
    n0 = users[-1]
    e_by_k = [r["energy_total"] for r in rows if r["N"] == n0]
    checks["energy_down_with_subcarriers"] = e_by_k[-1] <= e_by_k[0] * 1.35  # "roughly decreasing" (paper)
    return rows, checks
