"""Paper Fig. 5: energy & FL time vs (N users x K subcarriers).

Claims: more subcarriers -> energy/time trend down; more users (same K) ->
energy and FL time up.

Grid cells have different (N, K) shapes, so they cannot share one batch —
instead each cell averages over B i.i.d. channel realisations solved in ONE
`solve_batch` call (the paper's figures average over channel draws; the seed
solved a single realisation per cell in a Python loop).
"""
from __future__ import annotations

import jax

from .common import run_proposed_batch, weights, write_csv
from repro.scenarios import get_family

USERS = (4, 8, 16)
SUBCARRIERS = (20, 40, 60)


def run(quick: bool = True, seed: int = 0, scenario: str = "iid_rayleigh"):
    w = weights()
    family = get_family(scenario)
    rows = []
    users = USERS[:2] if quick else USERS
    subs = SUBCARRIERS[:2] if quick else SUBCARRIERS
    n_real = 2 if quick else 4
    for n in users:
        for k in subs:
            pb = family.sample_batch(jax.random.PRNGKey(seed), n_real, N=n, K=k)
            reps = run_proposed_batch(pb, w)
            # mean over channel realisations, one row per grid cell
            rep = {key: sum(r[key] for r in reps) / n_real for key in reps[0]}
            rows.append({"N": n, "K": k, "n_realisations": n_real, **rep})
    write_csv("fig5_users_subcarriers", rows)

    checks = {}
    # more users at fixed K => more energy
    k0 = subs[0]
    e_by_n = [r["energy_total"] for r in rows if r["K"] == k0]
    checks["energy_up_with_users"] = e_by_n[-1] >= e_by_n[0] * 0.9
    t_by_n = [r["t_fl"] for r in rows if r["K"] == k0]
    checks["tfl_up_with_users"] = t_by_n[-1] >= t_by_n[0] * 0.9
    # more subcarriers at fixed N => energy not worse
    n0 = users[-1]
    e_by_k = [r["energy_total"] for r in rows if r["N"] == n0]
    checks["energy_down_with_subcarriers"] = e_by_k[-1] <= e_by_k[0] * 1.35  # "roughly decreasing" (paper)
    return rows, checks
